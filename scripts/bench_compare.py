"""Diff two serving_bench records and flag perf regressions.

The tracked-trajectory tool: serving_bench stamps every record with a
`meta` provenance block (schema version, git rev, library versions);
this script loads an old and a new record, checks they are comparable
(same schema / arch / workload), diffs every throughput and latency
metric it can find, and exits nonzero when any regresses beyond the
threshold — throughput drops or latency rises by more than
``--threshold`` (default 10%).

    PYTHONPATH=src python scripts/bench_compare.py \
        experiments/serving/bench_smollm-135m_uniform.json new.json \
        --threshold 0.15

Importable: ``compare(old, new, threshold)`` returns a structured
report (used by tests/test_observability.py). Records from different
schema versions, archs, or workloads refuse to compare; records whose
meta (git rev, backend, versions) differs still compare but the report
says what changed, so a regression can be attributed.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional, Tuple

# metric paths diffed between records: (dotted path, higher_is_better)
METRICS: List[Tuple[str, bool]] = [
    ("baseline.tokens_per_s", True),
    ("engine.tokens_per_s", True),
    ("engine.ttft_p50_ms", False),
    ("engine.ttft_p95_ms", False),
    ("engine.ttft_p99_ms", False),
    ("engine.latency_p50_ms", False),
    ("engine.latency_p95_ms", False),
    ("engine.latency_p99_ms", False),
    ("engine.tpot_p50_ms", False),
    ("engine.tpot_p95_ms", False),
    ("engine.tpot_p99_ms", False),
    ("speedup", True),
    ("engine_speculative.tokens_per_s", True),
    ("engine_speculative.speculation.acceptance_rate", True),
    ("spec_speedup", True),
    ("engine_sampled.tokens_per_s", True),
    ("engine_no_prefix_cache.tokens_per_s", True),
    ("prefill_tokens_saved", True),
    ("engine.prefill.cached_tokens", True),
    ("engine_tiered.tokens_per_s", True),
    ("engine_tiered.prefill.cached_tokens", True),
    ("tiered_cached_tokens_gained", True),
    ("tiered_gate.host_revivals", True),
    # bursty / autoscaled arms: tail TTFT is the SLO a burst breaks and
    # elasticity exists to protect — the p99 paths below are the gate
    ("fixed.tokens_per_s", True),
    ("fixed.ttft_p99_ms", False),
    ("autoscaled.tokens_per_s", True),
    ("autoscaled.ttft_p95_ms", False),
    ("autoscaled.ttft_p99_ms", False),
    ("autoscale_gate.ttft_p99_win", True),
    ("autoscale_gate.scale_out_events", True),
    ("autoscale_gate.scale_in_events", True),
    # SLO arm: the burn-rate detection, shed/defer actuation, and the
    # sketch-vs-exact p99 accuracy bound must all keep holding (bools
    # compare as 0/1 — a flip to 0 is a >100% regression)
    ("slo.tokens_per_s", True),
    ("slo_gate.burn_rate_detected", True),
    ("slo_gate.shed_or_deferred", True),
    ("slo_gate.sketch_p99_within_bound", True),
]


def _get(record: Dict[str, Any], path: str) -> Optional[float]:
    node: Any = record
    for part in path.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return float(node) if isinstance(node, (int, float)) else None


def _comparable(old: Dict, new: Dict) -> Optional[str]:
    """Reason the two records must NOT be diffed, or None if they may."""
    for key in ("arch", "workload"):
        if old.get(key) != new.get(key):
            return (f"{key} differs: {old.get(key)!r} vs {new.get(key)!r}")
    old_schema = (old.get("meta") or {}).get("schema")
    new_schema = (new.get("meta") or {}).get("schema")
    if old_schema != new_schema:
        return f"schema differs: {old_schema!r} vs {new_schema!r}"
    return None


def compare(old: Dict, new: Dict, threshold: float = 0.10) -> Dict:
    """Structured diff of two bench records. Returns a report with a
    `regressions` list (metrics that moved the WRONG way by more than
    `threshold`, as a fraction), an `improvements` list, the full
    per-metric delta table, and `meta_changes` (provenance fields that
    differ — context for attributing a regression). Raises ValueError
    when the records are not comparable (different schema version,
    arch, or workload)."""
    reason = _comparable(old, new)
    if reason is not None:
        raise ValueError(f"records are not comparable: {reason}")
    deltas, regressions, improvements = [], [], []
    for path, higher_better in METRICS:
        a, b = _get(old, path), _get(new, path)
        if a is None or b is None:
            continue
        if a == 0:
            rel = 0.0 if b == 0 else float("inf") * (1 if b > 0 else -1)
        else:
            rel = (b - a) / abs(a)
        # "gain" is movement in the good direction
        gain = rel if higher_better else -rel
        row = {"metric": path, "old": a, "new": b,
               "change_pct": round(rel * 100, 2)}
        deltas.append(row)
        if gain < -threshold:
            regressions.append(row)
        elif gain > threshold:
            improvements.append(row)
    meta_changes = {}
    old_meta, new_meta = old.get("meta") or {}, new.get("meta") or {}
    for key in sorted(set(old_meta) | set(new_meta)):
        if old_meta.get(key) != new_meta.get(key):
            meta_changes[key] = {"old": old_meta.get(key),
                                 "new": new_meta.get(key)}
    return {
        "arch": old.get("arch"),
        "workload": old.get("workload"),
        "threshold_pct": round(threshold * 100, 2),
        "metrics": deltas,
        "regressions": regressions,
        "improvements": improvements,
        "meta_changes": meta_changes,
        "ok": not regressions,
    }


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two serving_bench records; exit 1 on any "
                    "regression beyond --threshold")
    ap.add_argument("old", help="baseline bench record (JSON)")
    ap.add_argument("new", help="candidate bench record (JSON)")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative regression tolerance (0.10 = 10%%)")
    args = ap.parse_args(argv)
    with open(args.old) as f:
        old = json.load(f)
    with open(args.new) as f:
        new = json.load(f)
    try:
        report = compare(old, new, args.threshold)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    for row in report["metrics"]:
        mark = ""
        if row in report["regressions"]:
            mark = "  <-- REGRESSION"
        elif row in report["improvements"]:
            mark = "  (improved)"
        print(f"{row['metric']},{row['old']},{row['new']},"
              f"{row['change_pct']:+.2f}%{mark}")
    for key, ch in report["meta_changes"].items():
        print(f"meta.{key},{ch['old']},{ch['new']},changed")
    if report["regressions"]:
        print(f"{len(report['regressions'])} regression(s) beyond "
              f"{report['threshold_pct']}%", file=sys.stderr)
        return 1
    print(f"ok: no regression beyond {report['threshold_pct']}%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
