#!/usr/bin/env bash
# Tier-1 verify: the full test suite from the repo root.
#   scripts/ci.sh            # everything
#   scripts/ci.sh -m 'not slow'
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
