#!/usr/bin/env bash
# Tier-1 verify, from the repo root.
#   scripts/ci.sh              # fast gate (default): -m 'not slow'
#   scripts/ci.sh fast         # same, explicitly
#   scripts/ci.sh full         # everything, including slow e2e tests
#   scripts/ci.sh serving      # serving tests (-m serving) + the
#                              # spec-decode smoke bench (fixed seed;
#                              # asserts acceptance > 0, greedy arm
#                              # bit-identical to generate(), and —
#                              # sampled-speculation gates — sampled
#                              # acceptance > 0 + batch-composition
#                              # invariance of sampled outputs) + the
#                              # 2-replica router smoke (fixed seed,
#                              # multi-tenant workload; asserts every
#                              # cluster arm — greedy / sampled / spec,
#                              # all three policies — bit-identical to
#                              # the 1-replica run, and that
#                              # prefix-affinity cache-skips strictly
#                              # more prompt tokens than round-robin)
#   scripts/ci.sh <pytest args...>   # passthrough (back-compat)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
case "${1:-fast}" in
  fast)    shift || true; exec python -m pytest -x -q -m 'not slow' "$@" ;;
  full)    shift;         exec python -m pytest -x -q "$@" ;;
  serving) shift
           python -m pytest -x -q -m serving "$@"
           python benchmarks/serving_bench.py --workload repetitive \
                --smoke --seed 0 --temperature 0.8 --top-k 2 \
                --out "$(mktemp -d)"
           exec python benchmarks/serving_bench.py \
                --workload multi-tenant --smoke --replicas 2 --seed 0 \
                --out "$(mktemp -d)" ;;
  *)                      exec python -m pytest -x -q "$@" ;;
esac
