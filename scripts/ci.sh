#!/usr/bin/env bash
# Tier-1 verify, from the repo root.
#   scripts/ci.sh              # fast gate (default): -m 'not slow'
#   scripts/ci.sh fast         # same, explicitly
#   scripts/ci.sh full         # everything, including slow e2e tests
#   scripts/ci.sh serving      # serving tests (-m serving) + the
#                              # spec-decode smoke bench (fixed seed;
#                              # asserts acceptance > 0, greedy arm
#                              # bit-identical to generate(), and —
#                              # sampled-speculation gates — sampled
#                              # acceptance > 0 + batch-composition
#                              # invariance of sampled outputs) + the
#                              # 2-replica router smoke (fixed seed,
#                              # multi-tenant workload; asserts every
#                              # cluster arm — greedy / sampled / spec,
#                              # all three policies — bit-identical to
#                              # the 1-replica run, and that
#                              # prefix-affinity cache-skips strictly
#                              # more prompt tokens than round-robin)
#                              # + the observability smoke: serve.py
#                              # emits --trace-out/--metrics-out, both
#                              # exports are schema-validated, and the
#                              # bench obs arm asserts outputs stay
#                              # bit-identical with tracing enabled
#                              # + the long-context smoke (chunked
#                              # admission identity + flat peak score
#                              # bytes) + the uniform-workload
#                              # regression gate: a fresh smoke-sized
#                              # uniform bench diffed against the
#                              # committed record via bench_compare
#                              # + the quantized/tiered KV smoke:
#                              # serve.py end-to-end on int8 pools with
#                              # a host spill tier, then gates — fp16
#                              # pools bit-identical, int8 greedy
#                              # within the pinned per-token divergence
#                              # budget (<= 10% on the fixed workload),
#                              # >= 1 host-tier revival with output
#                              # unchanged — and the shared-prefix
#                              # regression gate: the committed record
#                              # (incl. its tiered arm) re-run and
#                              # diffed via bench_compare
#                              # + the autoscale smoke: serve.py on a
#                              # bursty workload with elastic replicas
#                              # (>= 1 scale-out and >= 1 scale-in in
#                              # the metrics dump, autoscaled outputs
#                              # bit-identical to a fixed-size run) and
#                              # the bursty regression gate against the
#                              # committed record (incl. its SLO arm:
#                              # burn rate > 1 in-burst, >= 1 shed or
#                              # deferral, sketch p99 within its bound)
#                              # + the SLO smoke: serve.py with a TTFT
#                              # objective + --slo-shed + the flight
#                              # recorder; the metrics dump must carry
#                              # the shed counter, burn-rate gauges and
#                              # quantile sketches, and the flight dump
#                              # must schema-validate
#   scripts/ci.sh <pytest args...>   # passthrough (back-compat)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
case "${1:-fast}" in
  fast)    shift || true; exec python -m pytest -x -q -m 'not slow' "$@" ;;
  full)    shift;         exec python -m pytest -x -q "$@" ;;
  serving) shift
           python -m pytest -x -q -m serving "$@"
           # observability smoke: a tiny served run must export a valid
           # Perfetto trace + metrics dump (serve.py exits nonzero on
           # schema errors; re-validated here from the files on disk)
           obs_dir="$(mktemp -d)"
           python -m repro.launch.serve --requests 4 --slots 2 \
                --prompt-len 8 16 --max-new 2 4 --seed 0 \
                --trace-out "$obs_dir/trace.json" \
                --metrics-out "$obs_dir/metrics.json"
           python - "$obs_dir" <<'PY'
import json, sys
from repro.serving.observability import (validate_metrics_dump,
                                         validate_trace_events)
d = sys.argv[1]
with open(f"{d}/trace.json") as f:
    errs = validate_trace_events(json.load(f))
assert not errs, errs
with open(f"{d}/metrics.json") as f:
    errs = validate_metrics_dump(json.load(f))
assert not errs, errs
print("observability exports valid")
PY
           # bench smokes (the repetitive one also asserts the obs-arm
           # bit-identity gate: tracing on == tracing off, counters
           # reconcile, exporters valid)
           python benchmarks/serving_bench.py --workload repetitive \
                --smoke --seed 0 --temperature 0.8 --top-k 2 \
                --out "$(mktemp -d)"
           # long-context smoke: chunked admission bit-identity to
           # generate() + peak score bytes flat past the chunk budget
           python benchmarks/serving_bench.py --workload long-context \
                --smoke --seed 0 --out "$(mktemp -d)"
           # uniform regression gate: rerun the committed record's
           # exact workload and diff throughput/latency against it
           # (generous threshold — shared CI boxes are noisy; it
           # catches collapses, not jitter)
           cmp_dir="$(mktemp -d)"
           python benchmarks/serving_bench.py --workload uniform \
                --seed 0 --out "$cmp_dir"
           python scripts/bench_compare.py \
                experiments/serving/bench_smollm-135m_uniform.json \
                "$cmp_dir/bench_smollm-135m_uniform.json" \
                --threshold 0.5
           # quantized + tiered KV smoke: serve.py runs int8 pools with
           # a host spill tier end-to-end, then the correctness gates
           python -m repro.launch.serve --requests 6 --slots 2 \
                --prompt-len 12 24 --max-new 2 4 --seed 0 \
                --kv-dtype int8 --host-cache-blocks 16
           python - <<'PY'
import jax
import numpy as np
from repro.configs import get_config
from repro.models import lm
from repro.serving.engine import (ServingEngine, shared_prefix_requests,
                                  synthetic_requests)

cfg = get_config("smollm-135m").reduced()
params = lm.init_params(jax.random.PRNGKey(0), cfg)

def run(reqs, max_seq, slots=4, **kw):
    eng = ServingEngine(params, cfg, num_slots=slots, block_size=16,
                        max_seq_len=max_seq, **kw)
    done = eng.run(list(reqs))
    return {c.rid: list(map(int, c.tokens)) for c in done}, eng

def mk():
    return synthetic_requests(8, vocab_size=cfg.vocab_size,
                              prompt_len=(16, 48), max_new=(8, 16), seed=0)

base, _ = run(mk(), 80)
fp16, _ = run(mk(), 80, kv_dtype="fp16")
assert base == fp16, "fp16 pools changed greedy output"
i8, _ = run(mk(), 80, kv_dtype="int8")
tot = sum(len(v) for v in base.values())
mism = sum(x != y for r in base for x, y in zip(base[r], i8[r]))
# pinned per-token divergence budget for int8 pools on this exact
# fixed-seed workload (measured 0 flips; 10% leaves margin for
# numeric jitter while still catching a broken quantizer outright)
assert mism / tot <= 0.10, f"int8 divergence {mism}/{tot} over budget"
print(f"kv_int8_divergence,{mism}/{tot},<= 10% budget")

def sp():
    # 4 rotating system prompts against a slots-only pool: every
    # admission evicts the other prefixes, so the host tier must
    # demote and later revive chains to keep them cached
    return shared_prefix_requests(16, vocab_size=cfg.vocab_size,
                                  prefix_len=48, suffix_len=(8, 16),
                                  max_new=(4, 8), n_prefixes=4, seed=0)

dev, _ = run(sp(), 96, slots=2, prefix_cache=True, num_blocks=13)
tier, eng = run(sp(), 96, slots=2, prefix_cache=True, num_blocks=13,
                host_cache_blocks=32)
assert dev == tier, "host spill tier changed greedy output"
assert eng.allocator.host_revivals >= 1, "host tier never revived"
print(f"kv_host_revivals,{eng.allocator.host_revivals},output unchanged")
PY
           # shared-prefix regression gate: rerun the committed
           # record's workload (incl. the tiered host-RAM arm and its
           # built-in identity/revival asserts) and diff cached-token
           # + throughput metrics against the committed record
           spx_dir="$(mktemp -d)"
           python benchmarks/serving_bench.py --workload shared-prefix \
                --seed 0 --out "$spx_dir"
           python scripts/bench_compare.py \
                experiments/serving/bench_smollm-135m_shared-prefix.json \
                "$spx_dir/bench_smollm-135m_shared-prefix.json" \
                --threshold 0.5
           # autoscale smoke: serve.py end-to-end on a bursty workload
           # with elastic replicas — the metrics dump must record at
           # least one scale-out AND one scale-in (cold standby stacks
           # make the burst pressure sustain past the policy windows
           # even at tiny decode lengths)
           as_dir="$(mktemp -d)"
           python -m repro.launch.serve --workload bursty --requests 20 \
                --slots 2 --prompt-len 8 16 --max-new 2 4 \
                --burst-rate 400 --base-rate 2 --burst-every 30 \
                --burst-len 0.04 --autoscale --min-replicas 1 \
                --max-replicas 3 --priorities 0 1 --seed 0 \
                --metrics-out "$as_dir/metrics.json"
           python - "$as_dir" <<'PY'
import json, sys
with open(f"{sys.argv[1]}/metrics.json") as f:
    doc = json.load(f)
vals = {c["name"]: c["value"] for c in doc["counters"]}
out_n = vals.get("autoscaler_scale_out_total", 0)
in_n = vals.get("autoscaler_scale_in_total", 0)
assert out_n >= 1, f"no scale-out recorded ({vals})"
assert in_n >= 1, f"no scale-in recorded ({vals})"
print(f"autoscale_events,out={out_n},in={in_n}")
PY
           # ...and elasticity must be invisible in the tokens: the
           # same bursty workload through an autoscaled cluster is
           # bit-identical to a fixed single-replica engine
           python - <<'PY'
import jax
from repro.configs import get_config
from repro.models import lm
from repro.serving.autoscaler import Autoscaler, AutoscalePolicy
from repro.serving.engine import ServingEngine, bursty_requests
from repro.serving.replica import Replica
from repro.serving.router import Router

cfg = get_config("smollm-135m").reduced()
params = lm.init_params(jax.random.PRNGKey(0), cfg)

def mk():
    return bursty_requests(12, vocab_size=cfg.vocab_size, base_rate=2.0,
                           burst_rate=400.0, burst_every=30.0,
                           burst_len=0.03, prompt_len=(8, 16),
                           max_new=(2, 4), priorities=(0, 1), seed=0)

kw = dict(num_slots=2, block_size=4, max_seq_len=32, prefill_max_batch=2)
eng = ServingEngine(params, cfg, **kw)
fixed = {c.rid: list(map(int, c.tokens)) for c in eng.run(mk())}
reps = [Replica(params, cfg, replica_id=i, **kw) for i in range(3)]
router = Router(reps[:1], policy="least-loaded")
Autoscaler(router, policy=AutoscalePolicy(min_replicas=1, max_replicas=3,
                                          cooldown_s=0.1),
           standby=reps[1:])
auto = {c.rid: list(map(int, c.tokens)) for c in router.run(mk())}
assert fixed == auto, "autoscaled cluster changed greedy output"
print(f"autoscale_identity,{len(fixed)} requests,bit-identical")
PY
           # bursty regression gate: rerun the committed autoscale
           # record (its built-in gates assert >=1 scale-out/in and the
           # p99-TTFT win) and diff tail latency against the record
           ab_dir="$(mktemp -d)"
           python benchmarks/serving_bench.py --workload bursty \
                --seed 0 --out "$ab_dir"
           python scripts/bench_compare.py \
                experiments/serving/bench_smollm-135m_bursty.json \
                "$ab_dir/bench_smollm-135m_bursty.json" \
                --threshold 0.5
           # SLO smoke: serve.py end-to-end on the burst with a TTFT
           # objective, shedding armed under an aggressive deadline,
           # and the flight recorder on — the metrics dump must carry
           # the shed counter + burn-rate gauges + quantile sketches,
           # and the anomaly dump must be a schema-valid Perfetto trace
           slo_dir="$(mktemp -d)"
           python -m repro.launch.serve --workload bursty --requests 20 \
                --slots 2 --prompt-len 8 16 --max-new 2 4 \
                --burst-rate 400 --base-rate 2 --burst-every 30 \
                --burst-len 0.04 --seed 0 \
                --slo-ttft-ms 20 --slo-shed --deadline-ms 120 \
                --flight-recorder "$slo_dir/flight.json" \
                --metrics-out "$slo_dir/metrics.json"
           python - "$slo_dir" <<'PY'
import json, sys
from repro.serving.observability import (validate_metrics_dump,
                                         validate_trace_events)
d = sys.argv[1]
with open(f"{d}/metrics.json") as f:
    doc = json.load(f)
assert not validate_metrics_dump(doc), "metrics dump invalid"
names = {c["name"] for c in doc["counters"]}
assert "slo_shed_total" in names, f"no shed counter ({sorted(names)})"
gauges = {g["name"] for g in doc["gauges"]}
assert {"slo_burn_rate_fast_gauge",
        "slo_burn_rate_slow_gauge"} <= gauges, f"burn gauges missing"
assert doc.get("sketches"), "quantile sketches missing from dump"
assert doc.get("slo", {}).get("peak_burn"), "slo snapshot missing"
with open(f"{d}/flight.json") as f:
    errs = validate_trace_events(json.load(f))
assert not errs, errs
print("slo smoke: shed counter + burn gauges + sketches + "
      "flight dump valid")
PY
           exec python benchmarks/serving_bench.py \
                --workload multi-tenant --smoke --replicas 2 --seed 0 \
                --out "$(mktemp -d)" ;;
  *)                      exec python -m pytest -x -q "$@" ;;
esac
