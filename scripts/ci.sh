#!/usr/bin/env bash
# Tier-1 verify, from the repo root.
#   scripts/ci.sh              # fast gate (default): -m 'not slow'
#   scripts/ci.sh fast         # same, explicitly
#   scripts/ci.sh full         # everything, including slow e2e tests
#   scripts/ci.sh serving      # serving tests (-m serving) + the
#                              # spec-decode smoke bench (fixed seed;
#                              # asserts acceptance > 0, greedy arm
#                              # bit-identical to generate(), and —
#                              # sampled-speculation gates — sampled
#                              # acceptance > 0 + batch-composition
#                              # invariance of sampled outputs) + the
#                              # 2-replica router smoke (fixed seed,
#                              # multi-tenant workload; asserts every
#                              # cluster arm — greedy / sampled / spec,
#                              # all three policies — bit-identical to
#                              # the 1-replica run, and that
#                              # prefix-affinity cache-skips strictly
#                              # more prompt tokens than round-robin)
#                              # + the observability smoke: serve.py
#                              # emits --trace-out/--metrics-out, both
#                              # exports are schema-validated, and the
#                              # bench obs arm asserts outputs stay
#                              # bit-identical with tracing enabled
#                              # + the long-context smoke (chunked
#                              # admission identity + flat peak score
#                              # bytes) + the uniform-workload
#                              # regression gate: a fresh smoke-sized
#                              # uniform bench diffed against the
#                              # committed record via bench_compare
#   scripts/ci.sh <pytest args...>   # passthrough (back-compat)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
case "${1:-fast}" in
  fast)    shift || true; exec python -m pytest -x -q -m 'not slow' "$@" ;;
  full)    shift;         exec python -m pytest -x -q "$@" ;;
  serving) shift
           python -m pytest -x -q -m serving "$@"
           # observability smoke: a tiny served run must export a valid
           # Perfetto trace + metrics dump (serve.py exits nonzero on
           # schema errors; re-validated here from the files on disk)
           obs_dir="$(mktemp -d)"
           python -m repro.launch.serve --requests 4 --slots 2 \
                --prompt-len 8 16 --max-new 2 4 --seed 0 \
                --trace-out "$obs_dir/trace.json" \
                --metrics-out "$obs_dir/metrics.json"
           python - "$obs_dir" <<'PY'
import json, sys
from repro.serving.observability import (validate_metrics_dump,
                                         validate_trace_events)
d = sys.argv[1]
with open(f"{d}/trace.json") as f:
    errs = validate_trace_events(json.load(f))
assert not errs, errs
with open(f"{d}/metrics.json") as f:
    errs = validate_metrics_dump(json.load(f))
assert not errs, errs
print("observability exports valid")
PY
           # bench smokes (the repetitive one also asserts the obs-arm
           # bit-identity gate: tracing on == tracing off, counters
           # reconcile, exporters valid)
           python benchmarks/serving_bench.py --workload repetitive \
                --smoke --seed 0 --temperature 0.8 --top-k 2 \
                --out "$(mktemp -d)"
           # long-context smoke: chunked admission bit-identity to
           # generate() + peak score bytes flat past the chunk budget
           python benchmarks/serving_bench.py --workload long-context \
                --smoke --seed 0 --out "$(mktemp -d)"
           # uniform regression gate: rerun the committed record's
           # exact workload and diff throughput/latency against it
           # (generous threshold — shared CI boxes are noisy; it
           # catches collapses, not jitter)
           cmp_dir="$(mktemp -d)"
           python benchmarks/serving_bench.py --workload uniform \
                --seed 0 --out "$cmp_dir"
           python scripts/bench_compare.py \
                experiments/serving/bench_smollm-135m_uniform.json \
                "$cmp_dir/bench_smollm-135m_uniform.json" \
                --threshold 0.5
           exec python benchmarks/serving_bench.py \
                --workload multi-tenant --smoke --replicas 2 --seed 0 \
                --out "$(mktemp -d)" ;;
  *)                      exec python -m pytest -x -q "$@" ;;
esac
