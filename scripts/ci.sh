#!/usr/bin/env bash
# Tier-1 verify, from the repo root.
#   scripts/ci.sh              # fast gate (default): -m 'not slow'
#   scripts/ci.sh fast         # same, explicitly
#   scripts/ci.sh full         # everything, including slow e2e tests
#   scripts/ci.sh serving      # serving subsystem only (-m serving)
#   scripts/ci.sh <pytest args...>   # passthrough (back-compat)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
case "${1:-fast}" in
  fast)    shift || true; exec python -m pytest -x -q -m 'not slow' "$@" ;;
  full)    shift;         exec python -m pytest -x -q "$@" ;;
  serving) shift;         exec python -m pytest -x -q -m serving "$@" ;;
  *)                      exec python -m pytest -x -q "$@" ;;
esac
