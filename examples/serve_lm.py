"""Serve a small LM two ways: the legacy fixed-batch generate() path,
and the continuous-batching engine with per-request SamplingParams and
streaming completions (greedy + sampled lanes in one batch).

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b
(recurrent archs demonstrate O(1)-state decode; attention archs the KV
cache path — both reduced configs on CPU.)
"""
import argparse
import time

import jax
import numpy as np

from repro import compat
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import generate
from repro.models import lm
from repro.serving import Request, SamplingParams, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    if cfg.frontend == "audio":
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (args.batch, 8, cfg.n_codebooks), 0,
                                     cfg.vocab_size)
    else:
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (args.batch, 8), 0, cfg.vocab_size)
    with compat.set_mesh(make_host_mesh()):
        t0 = time.time()
        toks = generate(params, cfg, prompts, args.gen, temperature=0.8)
        dt = time.time() - t0
        print(f"{args.arch}: generated {toks.shape} tokens in {dt:.2f}s "
              f"(legacy fixed-batch path)")
        print("sample:", toks[0][:12])

        if cfg.frontend != "none":
            return                      # engine serves text LMs only
        engine = ServingEngine(params, cfg, num_slots=2, block_size=8,
                               max_seq_len=8 + args.gen + 1)
        requests = [
            Request(rid=0, prompt=np.asarray(prompts[0]),
                    max_new_tokens=args.gen),          # greedy lane
            Request(rid=1, prompt=np.asarray(prompts[1]),
                    sampling=SamplingParams(temperature=0.8, top_k=50,
                                            seed=7, logprobs=True,
                                            max_new_tokens=args.gen)),
        ]
        print("streaming (greedy + sampled lanes in one batch):")
        for ev in engine.stream(requests):
            if ev.done:
                c = ev.completion
                print(f"  req {c.rid} done ({c.finish_reason}): "
                      f"{len(c.tokens)} tokens"
                      + (f", mean logprob {c.logprobs.mean():.2f}"
                         if c.logprobs is not None else ""))
            else:
                print(f"  req {ev.rid} += {ev.tokens}")


if __name__ == "__main__":
    main()
