"""Serve a small LM with batched requests: prefill + sampled decode.

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b
(recurrent archs demonstrate O(1)-state decode; attention archs the KV
cache path — both reduced configs on CPU.)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import generate
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    if cfg.frontend == "audio":
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (args.batch, 8, cfg.n_codebooks), 0,
                                     cfg.vocab_size)
    else:
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (args.batch, 8), 0, cfg.vocab_size)
    with compat.set_mesh(make_host_mesh()):
        t0 = time.time()
        toks = generate(params, cfg, prompts, args.gen, temperature=0.8)
        dt = time.time() - t0
    print(f"{args.arch}: generated {toks.shape} tokens in {dt:.2f}s")
    print("sample:", toks[0][:12])


if __name__ == "__main__":
    main()
