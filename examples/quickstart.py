"""Quickstart: the paper's algorithm on its own workload.

Distributed stochastic least squares with m=8 machines: run MP-DSVRG
(Algorithm 1) and MP-DANE (Algorithm 2) against minibatch SGD and verify the
communication / memory / statistical tradeoffs of Table 1.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import theory
from repro.core.baselines import run_acc_minibatch_sgd, run_minibatch_sgd
from repro.core.losses import loss_constants
from repro.core.mp_dane import run_mp_dane
from repro.core.mp_dsvrg import run_mp_dsvrg
from repro.data.synthetic import LeastSquaresStream


def main():
    stream = LeastSquaresStream(dim=64, noise=0.1, seed=0)
    X, y = stream.sample(jax.random.PRNGKey(1), 8192)
    L, beta = loss_constants(X, y, radius=1.0)
    spec = theory.ProblemSpec(L=L, beta=beta, B=1.0, dim=64)
    m, b, T = 8, 128, 8            # n = b*m*T = 8192 samples
    print(f"least squares d=64, m={m} machines, b={b}/machine, T={T} "
          f"outer steps (n = {b * m * T})\n")

    rows = []
    r = run_mp_dsvrg(stream, spec, m, b, T)
    rows.append(("MP-DSVRG (Alg.1)", r.w_avg, r.ledger))
    r = run_mp_dane(stream, spec, m, b, T, local_solver="saga",
                    eta_scale=0.1)
    rows.append(("MP-DANE  (Alg.2)", r.w_avg, r.ledger))
    r = run_minibatch_sgd(stream, spec, m, b, T)
    rows.append(("minibatch SGD", r.w_avg, r.ledger))
    r = run_acc_minibatch_sgd(stream, spec, m, b, T)
    rows.append(("acc. minibatch SGD", r.w_avg, r.ledger))

    print(f"{'method':22s} {'pop. subopt':>12s} {'comm rounds':>12s} "
          f"{'mem (vectors)':>14s}")
    for name, w, ledger in rows:
        sub = float(stream.population_suboptimality(w))
        print(f"{name:22s} {sub:12.5f} {ledger.comm_rounds:12d} "
              f"{ledger.peak_memory_vectors:14d}")
    bound = theory.rate_bound_weakly_convex(spec, b * m, T, exact=False)
    print(f"\nThm 7 bound at bT = {b * m * T}: {bound:.5f}")


if __name__ == "__main__":
    main()
