"""Train a ~100M-param LM for a few hundred steps with the MBProx optimizer
(the paper's technique as a deep-learning training step) and compare the
loss trajectory against the baseline AdamW data-parallel step.

    PYTHONPATH=src python examples/train_lm.py --steps 200 [--full]

--full uses the real smollm-135m config (135M params — slow on CPU);
otherwise the reduced config exercises the identical code path.
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    print("=== MBProx (paper technique: local prox solves, 2 syncs/step) ===")
    _, mb_losses = train("smollm-135m", args.steps, optimizer="mbprox",
                         lr=5e-2, reduced=not args.full, log_every=25)
    print("\n=== baseline (AdamW, grad all-reduce every microbatch) ===")
    _, ad_losses = train("smollm-135m", args.steps, optimizer="baseline",
                         lr=2e-2, reduced=not args.full, log_every=25)
    print(f"\nMBProx   final/min loss: {mb_losses[-1]:.4f} / "
          f"{min(mb_losses):.4f}")
    print(f"baseline final/min loss: {ad_losses[-1]:.4f} / "
          f"{min(ad_losses):.4f}")
    print("data-axis collectives per outer step: MBProx 2, baseline "
          "n_micro (see EXPERIMENTS.md §Dry-run for the 256-chip counts)")


if __name__ == "__main__":
    main()
