"""Figure 1 / Figure 2 reproduction: the communication-memory tradeoff.

Sweeps the per-machine minibatch size b at FIXED sample budget n = b*m*T and
shows that (i) statistical error stays flat (Thm 7: any b works), while
(ii) communication falls and memory rises linearly in b — the paper's
central tradeoff. Also shows minibatch SGD degrading at large b (Fig. 3).

    PYTHONPATH=src python examples/convex_tradeoff.py
"""
import jax

from repro.core import theory
from repro.core.baselines import run_minibatch_sgd
from repro.core.losses import loss_constants
from repro.core.mp_dane import run_mp_dane
from repro.data.synthetic import LeastSquaresStream


def main():
    stream = LeastSquaresStream(dim=32, noise=0.1, seed=0)
    X, y = stream.sample(jax.random.PRNGKey(1), 8192)
    L, beta = loss_constants(X, y, radius=1.0)
    spec = theory.ProblemSpec(L=L, beta=beta, B=1.0, dim=32)
    m, n_local = 4, 2048           # fixed per-machine sample budget

    print(f"{'b':>6s} {'T':>5s} {'MP subopt':>11s} {'SGD subopt':>11s} "
          f"{'MP comm':>8s} {'MP mem':>7s}")
    for b in [32, 128, 512, 2048]:
        T = n_local // b
        mp = run_mp_dane(stream, spec, m, b, T, local_solver="exact")
        sgd = run_minibatch_sgd(stream, spec, m, b, T)
        sub_mp = float(stream.population_suboptimality(mp.w_avg))
        sub_sgd = float(stream.population_suboptimality(sgd.w_avg))
        print(f"{b:6d} {T:5d} {sub_mp:11.5f} {sub_sgd:11.5f} "
              f"{mp.ledger.comm_rounds:8d} "
              f"{mp.ledger.peak_memory_vectors:7d}")
    print("\nMP error is flat in b (Thm 7); SGD degrades once bm >> sqrt(n);"
          "\nMP communication falls ~1/b while memory grows ~b (Fig. 1).")


if __name__ == "__main__":
    main()
