"""Shared benchmark helpers."""
from __future__ import annotations

import time

import jax


def time_call(fn, *args, repeats: int = 5, warmup: int = 2):
    """Median wall time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
