"""Benchmark entry point: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (appendix_e_logistic, fig3_convergence,
                            fig_tradeoff, kernels_bench, rates, roofline,
                            table1_resources)
    table1_resources.run()
    fig_tradeoff.run()
    fig3_convergence.run()
    rates.run()
    appendix_e_logistic.run()
    kernels_bench.run()
    roofline.run()


if __name__ == "__main__":
    main()
