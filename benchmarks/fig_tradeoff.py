"""Figures 1-2: communication<->memory tradeoff of MP-DSVRG as b sweeps at
fixed sample budget; statistical error must stay flat (Thm 7/10)."""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.core import theory
from repro.core.losses import loss_constants
from repro.core.mp_dsvrg import run_mp_dsvrg
from repro.data.synthetic import LeastSquaresStream


def run():
    stream = LeastSquaresStream(dim=32, noise=0.1, seed=0)
    X, y = stream.sample(jax.random.PRNGKey(1), 4096)
    L, beta = loss_constants(X, y, radius=1.0)
    spec = theory.ProblemSpec(L=L, beta=beta, B=1.0, dim=32)
    m, n_local = 4, 1024
    for b in [32, 128, 512, 1024]:
        T = n_local // b
        t0 = time.perf_counter()
        res = run_mp_dsvrg(stream, spec, m, b, T)
        us = (time.perf_counter() - t0) * 1e6
        sub = float(stream.population_suboptimality(res.w_avg))
        emit(f"fig1_tradeoff/b={b}", us,
             f"subopt={sub:.5f};comm={res.ledger.comm_rounds};"
             f"mem={res.ledger.peak_memory_vectors}")


if __name__ == "__main__":
    run()
