"""Serving throughput: continuous-batching engine vs the seed baseline.

The seed path (launch/serve.generate) primes the KV cache one token at a
time in a Python loop, serves one fixed batch in lockstep, and every
sequence decodes to the longest request in its batch. The engine admits
several same-bucket prompts per batched prefill dispatch, shares cached
prompt-prefix blocks across sequences, and keeps the decode batch full
by evicting/admitting mid-flight. Both are warmed up (jit compile
excluded) and run the identical workload; useful tokens = each request's
own max_new_tokens.

Workloads (--workload):
  uniform        fixed prompt length (PR 1 scenario)
  mixed          uniform-random prompt lengths in [--prompt-len LO HI] —
                 exercises the power-of-two prefill length buckets: the
                 record includes prefill jit shapes vs distinct lengths
  shared-prefix  common system prompt + short per-request suffix — runs
                 the engine with the prefix cache ON and OFF and records
                 computed vs cached prefill tokens for both

    PYTHONPATH=src python benchmarks/serving_bench.py --arch smollm-135m \
        --workload shared-prefix --requests 24 --prefix-len 192 --slots 8

Writes the trajectory record to
experiments/serving/bench_<arch>_<workload>.json. Importable:
`run_bench([...])` returns the record (used by the CI smoke test).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import List, Optional

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import lm
from repro.serving.engine import (ServingEngine, shared_prefix_requests,
                                  summarize, synthetic_requests)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "serving")


def run_baseline(params, cfg, requests, batch: int):
    """Seed behavior: fixed batches, token-by-token priming, lockstep
    decode to the longest member; ragged prompt lengths right-pad to the
    batch max (the baseline has no ragged support — padding work counts
    against it exactly as it would in production).
    Returns (useful_tokens, seconds)."""
    groups = [requests[i:i + batch] for i in range(0, len(requests), batch)]
    useful = 0
    t0 = time.perf_counter()
    for group in groups:
        plen = max(len(r.prompt) for r in group)
        prompts = np.stack([np.pad(r.prompt, (0, plen - len(r.prompt)))
                            for r in group])
        gen = max(r.max_new_tokens for r in group)
        toks = generate(params, cfg, jax.numpy.asarray(prompts), gen)
        jax.block_until_ready(toks)
        useful += sum(r.max_new_tokens for r in group)
    return useful, time.perf_counter() - t0


def run_engine(engine, requests):
    done = engine.run(requests)
    useful = sum(len(c.tokens) for c in done)
    return useful, engine.wall_time, summarize(done, engine.wall_time,
                                               engine)


def _make_requests(args, cfg):
    if args.workload == "shared-prefix":
        return shared_prefix_requests(
            args.requests, vocab_size=cfg.vocab_size,
            prefix_len=args.prefix_len,
            suffix_len=tuple(args.suffix_len), max_new=tuple(args.max_new),
            n_prefixes=args.n_prefixes, seed=args.seed)
    plen = (args.prompt_len[0] if len(args.prompt_len) == 1
            else tuple(args.prompt_len))
    if args.workload == "mixed" and len(args.prompt_len) == 1:
        plen = (max(args.prompt_len[0] // 4, 1), args.prompt_len[0])
    return synthetic_requests(args.requests, vocab_size=cfg.vocab_size,
                              prompt_len=plen, max_new=tuple(args.max_new),
                              seed=args.seed)


def _measure_engine(params, cfg, args, reqs, max_seq, prefix_cache):
    engine = ServingEngine(params, cfg, num_slots=args.slots,
                           block_size=args.block_size, max_seq_len=max_seq,
                           prefix_cache=prefix_cache,
                           prefill_max_batch=args.prefill_batch)
    engine.run(reqs)                  # warm up jit on the workload shapes
    engine.reset_prefix_cache()       # measured pass starts cache-cold
    return run_engine(engine, reqs)


def run_bench(argv: Optional[List[str]] = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--workload", default="uniform",
                    choices=["uniform", "mixed", "shared-prefix"])
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, nargs="+", default=[256])
    ap.add_argument("--prefix-len", type=int, default=192,
                    help="shared system-prompt length (shared-prefix)")
    ap.add_argument("--suffix-len", type=int, nargs=2, default=(8, 64),
                    help="per-request suffix range (shared-prefix)")
    ap.add_argument("--n-prefixes", type=int, default=1)
    ap.add_argument("--max-new", type=int, nargs=2, default=(4, 32))
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--prefill-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _make_requests(args, cfg)
    max_seq = max(len(r.prompt) for r in reqs) + max(args.max_new) + 1

    # warm the baseline on the exact workload shapes too
    run_baseline(params, cfg, reqs, args.slots)
    base_tok, base_s = run_baseline(params, cfg, reqs, args.slots)

    eng_tok, eng_s, eng_stats = _measure_engine(params, cfg, args, reqs,
                                                max_seq, prefix_cache=None)

    base_tps = base_tok / base_s
    eng_tps = eng_tok / eng_s
    record = {
        "arch": args.arch,
        "workload": args.workload,
        "requests": args.requests,
        "prompt_lens": sorted({len(r.prompt) for r in reqs}),
        "max_new": list(args.max_new),
        "slots": args.slots,
        "block_size": args.block_size,
        "baseline": {"useful_tokens": base_tok, "wall_s": round(base_s, 3),
                     "tokens_per_s": round(base_tps, 2)},
        "engine": eng_stats,
        "speedup": round(eng_tps / base_tps, 2),
    }
    if args.workload == "shared-prefix":
        _, _, nocache = _measure_engine(params, cfg, args, reqs, max_seq,
                                        prefix_cache=False)
        record["engine_no_prefix_cache"] = nocache
        record["prefill_tokens_saved"] = (
            nocache["prefill"]["computed_tokens"]
            - eng_stats["prefill"]["computed_tokens"])
    print(f"serving_baseline_tok_s,{base_tps:.1f},")
    print(f"serving_engine_tok_s,{eng_tps:.1f},")
    print(f"serving_speedup,{record['speedup']:.2f},x over token-by-token")
    pf = eng_stats["prefill"]
    print(f"prefill_computed_tokens,{pf['computed_tokens']},"
          f"of {pf['prompt_tokens']} prompt tokens "
          f"({pf['cached_tokens']} cached)")
    print(f"prefill_jit_shapes,{pf['shapes']},"
          f"vs {len(record['prompt_lens'])} distinct prompt lengths "
          f"(bucket bound {pf['buckets']})")
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out,
                        f"bench_{args.arch}_{args.workload}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {path}")
    return record


def main():
    run_bench()


if __name__ == "__main__":
    main()
