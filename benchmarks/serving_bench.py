"""Serving throughput: continuous-batching engine vs the seed baseline.

The seed path (launch/serve.generate) primes the KV cache one token at a
time in a Python loop, serves one fixed batch in lockstep, and every
sequence decodes to the longest request in its batch. The engine prefills
each prompt in a single jit call and keeps the decode batch full by
evicting/admitting mid-flight. Both are warmed up (jit compile excluded)
and run the identical workload; useful tokens = each request's own
max_new_tokens.

    PYTHONPATH=src python benchmarks/serving_bench.py --arch smollm-135m \
        --requests 24 --prompt-len 128 --slots 8

Writes the trajectory record to experiments/serving/bench_<arch>.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import lm
from repro.serving.engine import (ServingEngine, summarize,
                                  synthetic_requests)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "serving")


def run_baseline(params, cfg, requests, batch: int):
    """Seed behavior: fixed batches, token-by-token priming, lockstep
    decode to the longest member. Returns (useful_tokens, seconds)."""
    groups = [requests[i:i + batch] for i in range(0, len(requests), batch)]
    useful = 0
    t0 = time.perf_counter()
    for group in groups:
        prompts = np.stack([r.prompt for r in group])
        gen = max(r.max_new_tokens for r in group)
        toks = generate(params, cfg, jax.numpy.asarray(prompts), gen)
        jax.block_until_ready(toks)
        useful += sum(r.max_new_tokens for r in group)
    return useful, time.perf_counter() - t0


def run_engine(engine, requests):
    done = engine.run(requests)
    useful = sum(len(c.tokens) for c in done)
    return useful, engine.wall_time, summarize(done, engine.wall_time,
                                               engine)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, nargs=2, default=(4, 32))
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    reqs = synthetic_requests(args.requests, vocab_size=cfg.vocab_size,
                              prompt_len=args.prompt_len,
                              max_new=tuple(args.max_new), seed=args.seed)
    max_seq = args.prompt_len + max(args.max_new) + 1
    engine = ServingEngine(params, cfg, num_slots=args.slots,
                           block_size=args.block_size, max_seq_len=max_seq)

    # warm up both paths on the EXACT workload shapes (incl. a ragged last
    # group) so jit compile stays out of the measurement; the engine run
    # also resets its step counters on the measured pass
    engine.run(reqs)
    run_baseline(params, cfg, reqs, args.slots)

    base_tok, base_s = run_baseline(params, cfg, reqs, args.slots)
    eng_tok, eng_s, eng_stats = run_engine(engine, reqs)

    base_tps = base_tok / base_s
    eng_tps = eng_tok / eng_s
    record = {
        "arch": args.arch,
        "requests": args.requests,
        "prompt_len": args.prompt_len,
        "max_new": list(args.max_new),
        "slots": args.slots,
        "block_size": args.block_size,
        "baseline": {"useful_tokens": base_tok, "wall_s": round(base_s, 3),
                     "tokens_per_s": round(base_tps, 2)},
        "engine": eng_stats,
        "speedup": round(eng_tps / base_tps, 2),
    }
    print(f"serving_baseline_tok_s,{base_tps:.1f},")
    print(f"serving_engine_tok_s,{eng_tps:.1f},")
    print(f"serving_speedup,{record['speedup']:.2f},x over token-by-token")
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"bench_{args.arch}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
