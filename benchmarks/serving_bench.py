"""Serving throughput: continuous-batching engine vs the seed baseline.

The seed path (launch/serve.generate) primes the KV cache one token at a
time in a Python loop, serves one fixed batch in lockstep, and every
sequence decodes to the longest request in its batch. The engine admits
several same-bucket prompts per batched prefill dispatch, shares cached
prompt-prefix blocks across sequences, and keeps the decode batch full
by evicting/admitting mid-flight. Both are warmed up (jit compile
excluded) and run the identical workload; useful tokens = each request's
own max_new_tokens.

Workloads (--workload):
  uniform        fixed prompt length (PR 1 scenario)
  mixed          uniform-random prompt lengths in [--prompt-len LO HI] —
                 exercises the power-of-two prefill length buckets: the
                 record includes prefill jit shapes vs distinct lengths
  shared-prefix  common system prompt + short per-request suffix — runs
                 the engine with the prefix cache ON and OFF and records
                 computed vs cached prefill tokens for both, plus a
                 TIERED arm: the same traffic with enough distinct
                 prefixes to thrash the slots-only pool, device-only vs
                 device + host-RAM spill tier (--host-cache-blocks),
                 gated on bit-identity, >= 1 host revival, and a strict
                 cached-prompt-token gain over device-only
  multi-tenant   --tenants distinct shared prefixes, interleaved
                 arrivals — the workload that separates prefix-affinity
                 routing from round-robin
  repetitive     short token pattern tiled through each prompt — the
                 n-gram speculation scenario
  bursty         modulated-Poisson spike + straggler tail with mixed
                 priority classes — an elastically autoscaled 1..3
                 replica cluster vs a fixed single replica, gated on
                 >= 1 scale-out AND scale-in, a strict p99 TTFT win for
                 the autoscaled arm, and bit-identity of both arms;
                 plus an SLO arm (declared TTFT objective + aggressive
                 per-request deadlines, shedding armed) gated on burn
                 rate > 1 during the burst, >= 1 shed or deferral, and
                 the streaming sketch's p99 TTFT within its declared
                 relative-error bound of the exact nearest-rank p99

With --replicas N (> 1) the record gains CLUSTER arms: the same
workload through a Router over N full replica engine stacks, once per
placement policy (round-robin / least-loaded / prefix-affinity). Every
cluster arm is gated on BIT-IDENTITY to the corresponding
single-replica engine run — greedy, sampled (--temperature), and
speculative (--speculate) — because per-request realizations are
batch-composition independent, placement must never change output. The
record also logs per-policy placement counts and cluster-wide
cached-prompt-token totals (on multi-tenant traffic, prefix-affinity
must cache-skip strictly more than round-robin — the smoke gate).

With --speculate K a second engine arm runs with n-gram speculative
decoding; the record adds acceptance rate and tokens-per-dispatch, and
EVERY spec-arm output is checked token-identical to generate() at
temperature 0 (the correctness gate — speculation must never change
greedy output).

With --temperature T (> 0, optionally --top-k/--top-p) two more arms
run with per-request SamplingParams (each request carries its own
seed): a sampled engine arm gated on BATCH-COMPOSITION INVARIANCE
(every request rerun alone must reproduce its batched output
bit-for-bit — the position-keyed-PRNG contract) and, when --speculate
is also on, a speculative-SAMPLING arm (Leviathan accept/reject) gated
on acceptance > 0 plus the same invariance. The record gains a
`sampling` block describing the config and a `sampling_gate` with the
results; greedy-only records are unchanged.

    PYTHONPATH=src python benchmarks/serving_bench.py --arch smollm-135m \
        --workload repetitive --requests 24 --speculate 4 --draft ngram

Every record carries a `meta` provenance block (schema version, git
rev, jax/numpy/python versions, backend) so `scripts/bench_compare.py`
can refuse to diff incomparable records, plus an `observability` arm:
the same workload on a fresh engine with the recorder ON, gated on
bit-identity to the recorder-off run, with the metrics snapshot
embedded and both exporter schemas validated. The perf arms always run
recorder-off so committed numbers stay comparable across revisions.

--smoke shrinks everything for the CI gate (fixed seed) and asserts
acceptance rate > 0, greedy bit-identity, the verify-compilation
bound, trace-on identity + exporter validity, and (with --temperature)
the sampled-arm gates. Writes the trajectory record to
experiments/serving/bench_<arch>_<workload>.json. Importable:
`run_bench([...])` returns the record (used by the CI smoke test).
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import time
from typing import List, Optional

import jax
import numpy as np

import dataclasses

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import lm
from repro.serving.autoscaler import Autoscaler, AutoscalePolicy
from repro.serving.bucketing import pick_bucket
from repro.serving.engine import (ServingEngine, bursty_requests,
                                  long_document_requests,
                                  multi_tenant_requests,
                                  repetitive_requests,
                                  shared_prefix_requests, summarize,
                                  synthetic_requests)
from repro.serving.observability import (Observability, metrics_dump,
                                         to_perfetto,
                                         validate_metrics_dump,
                                         validate_trace_events)
from repro.serving.replica import Replica
from repro.serving.router import POLICIES, Router, summarize_cluster
from repro.serving.sampling import SamplingParams
from repro.serving.slo import SLOPolicy, SLOTracker

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "serving")

# bump when the record layout changes incompatibly — bench_compare
# refuses to diff records across schema versions
BENCH_SCHEMA = "repro.serving.bench/v1"


def _git_rev() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _run_meta(args) -> dict:
    """Provenance block stamped into every bench record: enough to tell
    whether two records are comparable (code rev, library versions,
    backend) before diffing their numbers."""
    return {
        "schema": BENCH_SCHEMA,
        "git_rev": _git_rev(),
        "jax": jax.__version__,
        "numpy": np.__version__,
        "python": platform.python_version(),
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
    }


def run_baseline(params, cfg, requests, batch: int):
    """Seed behavior: fixed batches, token-by-token priming, lockstep
    decode to the longest member; ragged prompt lengths right-pad to the
    batch max (the baseline has no ragged support — padding work counts
    against it exactly as it would in production).
    Returns (useful_tokens, seconds)."""
    groups = [requests[i:i + batch] for i in range(0, len(requests), batch)]
    useful = 0
    t0 = time.perf_counter()
    for group in groups:
        plen = max(len(r.prompt) for r in group)
        prompts = np.stack([np.pad(r.prompt, (0, plen - len(r.prompt)))
                            for r in group])
        gen = max(r.max_new_tokens for r in group)
        toks = generate(params, cfg, jax.numpy.asarray(prompts), gen)
        jax.block_until_ready(toks)
        useful += sum(r.max_new_tokens for r in group)
    return useful, time.perf_counter() - t0


def run_engine(engine, requests):
    done = engine.run(requests)
    useful = sum(len(c.tokens) for c in done)
    return useful, engine.wall_time, summarize(done, engine.wall_time,
                                               engine), done


def _make_requests(args, cfg, sampling=None):
    if args.workload == "shared-prefix":
        return shared_prefix_requests(
            args.requests, vocab_size=cfg.vocab_size,
            prefix_len=args.prefix_len,
            suffix_len=tuple(args.suffix_len), max_new=tuple(args.max_new),
            n_prefixes=args.n_prefixes, sampling=sampling, seed=args.seed)
    if args.workload == "multi-tenant":
        return multi_tenant_requests(
            args.requests, vocab_size=cfg.vocab_size,
            n_tenants=args.tenants, prefix_len=args.prefix_len,
            suffix_len=tuple(args.suffix_len), max_new=tuple(args.max_new),
            sampling=sampling, seed=args.seed)
    plen = (args.prompt_len[0] if len(args.prompt_len) == 1
            else tuple(args.prompt_len))
    if args.workload == "repetitive":
        return repetitive_requests(
            args.requests, vocab_size=cfg.vocab_size, period=args.period,
            prompt_len=plen, max_new=tuple(args.max_new),
            sampling=sampling, seed=args.seed)
    if args.workload == "mixed" and len(args.prompt_len) == 1:
        plen = (max(args.prompt_len[0] // 4, 1), args.prompt_len[0])
    return synthetic_requests(args.requests, vocab_size=cfg.vocab_size,
                              prompt_len=plen, max_new=tuple(args.max_new),
                              sampling=sampling, seed=args.seed)


def _pool_blocks(args, max_seq):
    """Block-pool size: None (the engine's slots-only default) except on
    multi-tenant traffic, where the pool gets headroom to keep every
    tenant's prefix resident — without it the cached-free pool thrashes
    and the policy comparison measures eviction noise, not routing."""
    if args.workload != "multi-tenant":
        return None
    per_seq = -(-max_seq // args.block_size)
    per_prefix = -(-args.prefix_len // args.block_size)
    return 1 + args.slots * per_seq + args.tenants * per_prefix


def _measure_engine(params, cfg, args, reqs, max_seq, prefix_cache,
                    speculate: int = 0, host_cache_blocks: int = 0):
    engine = ServingEngine(params, cfg, num_slots=args.slots,
                           block_size=args.block_size, max_seq_len=max_seq,
                           num_blocks=_pool_blocks(args, max_seq),
                           prefix_cache=prefix_cache,
                           prefill_max_batch=args.prefill_batch,
                           speculate=speculate, draft=args.draft,
                           ngram=args.ngram, kv_dtype=args.kv_dtype,
                           host_cache_blocks=host_cache_blocks)
    engine.run(reqs)                  # warm up jit on the workload shapes
    engine.reset_prefix_cache()       # measured pass starts cache-cold
    return run_engine(engine, reqs), engine


def _cluster_replicas(params, cfg, args, max_seq, speculate=0):
    return [Replica(params, cfg, replica_id=i, num_slots=args.slots,
                    block_size=args.block_size, max_seq_len=max_seq,
                    num_blocks=_pool_blocks(args, max_seq),
                    prefill_max_batch=args.prefill_batch,
                    speculate=speculate, draft=args.draft,
                    ngram=args.ngram)
            for i in range(args.replicas)]


def _measure_cluster(replicas, reqs, policy):
    """One measured cluster pass: fresh Router, a warm pass under THIS
    policy first (each policy's placement reaches its own set of
    prefill shapes — warming with another policy would leave compiles
    inside the first measured arm's wall), then prefix caches reset so
    every policy's measured pass starts cache-cold (the cache-skip
    comparison must not inherit warm blocks)."""
    router = Router(replicas, policy=policy)
    for rep in replicas:
        rep.reset_prefix_cache()
    router.run(list(reqs))                # jit warm under this policy
    for rep in replicas:
        rep.reset_prefix_cache()
    done = router.run(list(reqs))
    return done, router


def _cluster_identical(done, ref_done) -> bool:
    """The cluster correctness gate: every completion bit-identical to
    the same request's single-replica-run output (a duplicated or
    dropped rid is a failure, not a crash)."""
    ref = {c.rid: c.tokens for c in ref_done}
    if {c.rid for c in done} != set(ref) or len(done) != len(ref):
        return False
    return all(np.array_equal(ref[c.rid], c.tokens) for c in done)


def _check_identity(params, cfg, reqs, done) -> bool:
    """The speculative-decode correctness gate: every engine output must
    be token-identical to a plain greedy generate() of its request."""
    by_rid = {c.rid: c.tokens for c in done}
    for r in reqs:
        exp = np.asarray(generate(params, cfg,
                                  np.asarray(r.prompt)[None],
                                  r.max_new_tokens))[0]
        if not np.array_equal(by_rid[r.rid], exp):
            return False
    return True


def _check_batch_invariance(engine, reqs, done, probes=2) -> bool:
    """The per-request sampling gate: a sampled request rerun ALONE (no
    batch mates, fresh engine state) must reproduce its batched output
    bit-for-bit — position-keyed PRNG makes the realization a pure
    function of (seed, positions), never of batch composition. The
    first `probes` requests are checked (None = every request — the
    smoke gate's setting; large benchmark runs probe a prefix to keep
    the gate's rerun cost bounded)."""
    by_rid = {c.rid: c.tokens for c in done}
    for r in (reqs if probes is None else reqs[:probes]):
        engine.reset_prefix_cache()
        solo = engine.run([dataclasses.replace(r, arrival=0.0)])
        if not np.array_equal(solo[0].tokens, by_rid[r.rid]):
            return False
    return True


def _run_long_context(args) -> dict:
    """Prompt-length-scaling arm (--workload long-context): one long-
    document request per length, admitted through chunked prefill. Per
    length the record keeps TTFT, the number of prefill chunk
    dispatches, and the analytic peak score-tile bytes of the largest
    dispatch — the quantity chunked admission + streamed attention
    bound. Gates: (a) greedy output at the identity length is
    bit-identical to unchunked generate(); (b) peak score bytes are
    FLAT across every chunked length (memory does not grow with the
    prompt once the chunk budget is hit). The seed baseline is skipped:
    token-by-token priming of a 32k prompt is not a comparison, it is a
    timeout."""
    cfg = get_config(args.arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    lengths = args.lengths or [1024, 2048, 4096, 8192, 16384, 32768]
    chunk = args.prefill_chunk or 1024
    if args.smoke:
        lengths = args.lengths or [128, 256, 512]
        chunk = min(chunk, 64)
    max_new = 4
    slots = min(args.slots, 2)    # single-stream arm: keep the pool small
    ident_len = (lengths[-1] if args.smoke
                 else (16384 if 16384 in lengths else lengths[-1]))
    rows = []
    ident_ok = None
    budget = None
    for L in lengths:
        reqs = long_document_requests(
            1, vocab_size=cfg.vocab_size, prompt_len=L,
            max_new=(max_new, max_new), seed=args.seed)
        engine = ServingEngine(params, cfg, num_slots=slots,
                               block_size=args.block_size,
                               max_seq_len=L + max_new + 1,
                               prefill_chunk=chunk)
        budget = engine.runner.prefill_chunk
        engine.run(list(reqs))    # warm jit on this length's shapes
        engine.reset_prefix_cache()
        done = engine.run(list(reqs))
        stats = summarize(done, engine.wall_time, engine)
        rows.append({
            "prompt_len": L,
            "ttft_ms": stats["ttft_p50_ms"],
            "chunks": stats["prefill"]["dispatches"],
            "peak_score_bytes": stats["prefill"]["peak_score_bytes"],
            "tokens_per_s": stats["tokens_per_s"],
        })
        print(f"long_context_{L},{stats['ttft_p50_ms']},ms TTFT "
              f"({rows[-1]['chunks']} chunks, "
              f"{rows[-1]['peak_score_bytes']} peak score bytes)")
        if L == ident_len:
            exp = np.asarray(generate(params, cfg,
                                      np.asarray(reqs[0].prompt)[None],
                                      max_new))[0]
            ident_ok = bool(np.array_equal(done[0].tokens, exp))
            print(f"long_context_identity,{ident_ok},"
                  f"chunked vs generate() at {L} tokens")
    chunked_rows = [r for r in rows if r["prompt_len"] > budget]
    flat = (len({r["peak_score_bytes"] for r in chunked_rows}) <= 1)
    print(f"long_context_peak_flat,{flat},"
          f"score bytes across chunked lengths > {budget}")
    assert ident_ok, "chunked admission changed greedy output"
    assert flat, "peak score bytes grew with prompt length"
    record = {
        "meta": _run_meta(args),
        "arch": args.arch,
        "workload": "long-context",
        "prefill_chunk": budget,
        "max_new": max_new,
        "slots": slots,
        "block_size": args.block_size,
        "scaling": rows,
        "identity": {"prompt_len": ident_len, "greedy_identical": ident_ok},
        "peak_score_flat_past_chunk": flat,
    }
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"bench_{args.arch}_long-context.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {path}")
    return record


def _run_bursty(args) -> dict:
    """Bursty-traffic arm (--workload bursty): an elastically autoscaled
    cluster vs a fixed single replica on the SAME modulated-Poisson
    workload — a spike of arrivals at t=0 followed by a sparse
    straggler tail, with mixed priority classes so the scheduler's
    preempt-resume path runs under burst pressure. The tail-latency
    claim elasticity makes is p99 TTFT: the fixed replica queues the
    burst behind its two slots while the autoscaler activates jit-warm
    standby stacks, so the autoscaled arm must win p99 TTFT STRICTLY,
    record at least one scale-out AND one scale-in, and stay
    bit-identical to both the fixed run and generate() — scaling and
    preemption are scheduling decisions, never output decisions. All
    three replicas (active + standby) are pre-warmed on the workload
    shapes so neither arm's wall clock contains jit compiles. The seed
    baseline is skipped: open-loop arrival timing is the whole point
    and the lockstep path has no notion of it."""
    cfg = get_config(args.arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    # the gates need the burst's drain time to dwarf the scale-out
    # latency (sustain window + cooldown + join): below ~32 in-burst
    # requests with ~48-token decodes the warm engine drains the spike
    # before added capacity can matter and the p99 comparison measures
    # noise — so this arm pins its own floor instead of --smoke sizing
    n = max(args.requests, 40)
    slots = min(args.slots, 2)    # keep the fixed arm genuinely tight
    max_new = (32, 64)
    # one burst at t=0 (the cycle starts in-burst; burst_every is set
    # past the run so it never recurs) sized to ~4/5 of the requests,
    # then a ~2 req/s straggler tail long enough for the low-load
    # window + cooldown to elapse while work still trickles
    reqs = bursty_requests(
        n, vocab_size=cfg.vocab_size, base_rate=2.0, burst_rate=400.0,
        burst_every=1000.0, burst_len=(n * 0.8) / 400.0,
        prompt_len=(8, 16), max_new=max_new, priorities=(0, 1),
        seed=args.seed)
    max_seq = max(len(r.prompt) for r in reqs) + max_new[1] + 1
    kwargs = dict(num_slots=slots, block_size=min(args.block_size, 4),
                  max_seq_len=max_seq, prefill_max_batch=2)
    warm = [dataclasses.replace(r, arrival=0.0) for r in reqs]

    fixed_engine = ServingEngine(params, cfg, **kwargs)
    fixed_engine.run(list(warm))
    fixed_engine.reset_prefix_cache()
    fixed_done = fixed_engine.run(list(reqs))
    fixed_stats = summarize(fixed_done, fixed_engine.wall_time,
                            fixed_engine)
    fixed_stats["preemptions"] = fixed_engine.scheduler.preemptions
    fixed_stats["resumes"] = fixed_engine.scheduler.resumes

    n_max = 3
    reps = [Replica(params, cfg, replica_id=i, **kwargs)
            for i in range(n_max)]
    for rep in reps:
        rep.engine.run(list(warm))
        rep.reset_prefix_cache()
    router = Router(reps[:1], policy="least-loaded")
    Autoscaler(router, policy=AutoscalePolicy(
        min_replicas=1, max_replicas=n_max, queue_high=2.0,
        queue_low=1.0, high_window_s=0.05, low_window_s=0.3,
        cooldown_s=0.15), standby=reps[1:])
    auto_done = router.run(list(reqs))
    auto_stats = summarize_cluster(auto_done, router.wall_time, router)
    asc = auto_stats["cluster"]["autoscaler"]

    ident_fixed = _check_identity(params, cfg, reqs, fixed_done)
    ident_auto = _cluster_identical(auto_done, fixed_done)
    win = round(fixed_stats["ttft_p99_ms"]
                / max(auto_stats["ttft_p99_ms"], 1e-9), 3)
    record = {
        "meta": _run_meta(args),
        "arch": args.arch,
        "workload": "bursty",
        "requests": n,
        "slots": slots,
        "max_new": list(max_new),
        "priorities": [0, 1],
        "fixed": fixed_stats,
        "autoscaled": auto_stats,
        "autoscale_gate": {
            "greedy_identical_fixed": ident_fixed,
            "greedy_identical_autoscaled": ident_auto,
            "scale_out_events": asc["scale_out_events"],
            "scale_in_events": asc["scale_in_events"],
            "reclaims": asc["reclaims"],
            "preemptions_fixed": fixed_stats["preemptions"],
            "ttft_p99_win": win,
        },
    }
    print(f"bursty_fixed_ttft_p99_ms,{fixed_stats['ttft_p99_ms']},"
          f"1 replica x {slots} slots "
          f"({fixed_stats['preemptions']} preemptions)")
    print(f"bursty_autoscaled_ttft_p99_ms,{auto_stats['ttft_p99_ms']},"
          f"1..{n_max} replicas ({asc['scale_out_events']} out / "
          f"{asc['scale_in_events']} in)")
    print(f"bursty_ttft_p99_win,{win},x fixed over autoscaled")
    print(f"bursty_identical,{ident_fixed and ident_auto},"
          f"fixed vs generate() and autoscaled vs fixed")
    # deterministic workload, timing-robust physics (the burst lands on
    # an undersized replica; the tail outlasts the low window) — gate
    # every run, not only --smoke
    assert ident_fixed, "fixed arm diverged from generate()"
    assert ident_auto, "autoscaling changed greedy output"
    assert asc["scale_out_events"] >= 1, "burst never triggered scale-out"
    assert asc["scale_in_events"] >= 1, "idle tail never scaled in"
    assert auto_stats["ttft_p99_ms"] < fixed_stats["ttft_p99_ms"], \
        "autoscaled arm did not improve p99 TTFT under burst"

    # ---- SLO arm: the same burst against a declared TTFT objective ----
    # a fixed single replica (the arm whose burst TTFTs blow any sane
    # objective — exactly when the SLO layer must act) with the tracker
    # on, aggressive per-request deadlines stamped, and shedding ARMED.
    # Gates: (a) the burst drives the fast-window burn rate past 1.0
    # (budget is being spent faster than sustainable); (b) under a
    # deadline far below the fixed arm's burst TTFT, at least one
    # request is shed or deferred; (c) the streaming sketch's p99 TTFT
    # lands within its declared relative-error bound of the exact
    # nearest-rank p99 over the same observations — the bounded-memory
    # estimator is trusted only because this gate pins it to ground
    # truth every run.
    slo_policy = SLOPolicy(ttft_objective_ms=50.0, error_budget=0.1)
    slo_tracker = SLOTracker(slo_policy)
    deadline_ms = 250.0
    slo_reqs = [dataclasses.replace(
        r, sampling=dataclasses.replace(r.sampling or SamplingParams(),
                                        deadline_ms=deadline_ms))
        for r in reqs]
    slo_engine = ServingEngine(params, cfg, slo_tracker=slo_tracker,
                               slo_shed=True, **kwargs)
    slo_engine.run(list(warm))        # jit-warm (deadlines are generous
    slo_engine.reset_prefix_cache()   # at arrival=0: nothing sheds)
    slo_tracker.reset()
    slo_done = slo_engine.run(list(slo_reqs))
    slo_stats = summarize(slo_done, slo_engine.wall_time, slo_engine)
    sched = slo_engine.scheduler
    served = [c for c in slo_done if c.finish_reason != "shed"]
    ttfts = sorted(max(c.t_first_token - c.arrival, 0.0) for c in served)
    # nearest-rank p99 — the same estimator the sketch uses, so the
    # relative-error bound holds by construction, not by luck
    exact_p99 = ttfts[min(-(-99 * len(ttfts) // 100) - 1, len(ttfts) - 1)]
    sketch_p99 = slo_tracker.ttft_quantile(0.99)
    rel = abs(sketch_p99 - exact_p99) / max(exact_p99, 1e-12)
    within = rel <= slo_tracker.rel_err + 1e-9
    peak_fast = slo_tracker.peak_burn["fast"]
    slo_gate = {
        "ttft_objective_ms": slo_policy.ttft_objective_ms,
        "deadline_ms": deadline_ms,
        "burn_rate_detected": peak_fast > 1.0,
        "peak_burn_fast": round(peak_fast, 3),
        "shed": sched.shed_requests,
        "deferrals": sched.deferrals,
        "shed_or_deferred": sched.shed_requests + sched.deferrals >= 1,
        "sketch_p99_ttft_ms": round(sketch_p99 * 1e3, 3),
        "exact_p99_ttft_ms": round(exact_p99 * 1e3, 3),
        "sketch_rel_err": round(rel, 6),
        "sketch_p99_within_bound": within,
    }
    record["slo"] = slo_stats
    record["slo_gate"] = slo_gate
    print(f"slo_peak_burn_fast,{slo_gate['peak_burn_fast']},"
          f"x budget over the {slo_policy.fast_window_s}s window "
          f"(objective {slo_policy.ttft_objective_ms}ms)")
    print(f"slo_shed,{sched.shed_requests},requests shed "
          f"({sched.deferrals} deferred) under {deadline_ms}ms deadline")
    print(f"slo_sketch_p99_ttft_ms,{slo_gate['sketch_p99_ttft_ms']},"
          f"vs {slo_gate['exact_p99_ttft_ms']} exact "
          f"(rel err {slo_gate['sketch_rel_err']})")
    assert slo_gate["burn_rate_detected"], \
        "burst never drove TTFT burn rate past 1.0"
    assert slo_gate["shed_or_deferred"], \
        "aggressive deadline shed/deferred nothing"
    assert within, (f"sketch p99 off by {rel:.4f} relative "
                    f"(bound {slo_tracker.rel_err})")

    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"bench_{args.arch}_bursty.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {path}")
    return record


def run_bench(argv: Optional[List[str]] = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--workload", default="uniform",
                    choices=["uniform", "mixed", "shared-prefix",
                             "multi-tenant", "repetitive", "long-context",
                             "bursty"])
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, nargs="+", default=[256])
    ap.add_argument("--prefix-len", type=int, default=192,
                    help="shared system-prompt length (shared-prefix / "
                         "multi-tenant)")
    ap.add_argument("--suffix-len", type=int, nargs=2, default=(8, 64),
                    help="per-request suffix range (shared-prefix / "
                         "multi-tenant)")
    ap.add_argument("--n-prefixes", type=int, default=1)
    ap.add_argument("--tenants", type=int, default=4,
                    help="distinct shared prefixes (multi-tenant)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="> 1 adds router cluster arms (one per "
                         "placement policy) gated on bit-identity to "
                         "the single-replica run")
    ap.add_argument("--period", type=int, default=6,
                    help="repeated-pattern length (repetitive)")
    ap.add_argument("--max-new", type=int, nargs=2, default=(4, 32))
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--prefill-batch", type=int, default=4)
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked-admission budget for the long-context "
                         "arm (default 1024; smoke caps it at 64)")
    ap.add_argument("--lengths", type=int, nargs="+", default=None,
                    help="prompt lengths for the long-context scaling "
                         "sweep (default 1024..32768 doubling)")
    ap.add_argument("--speculate", type=int, default=0,
                    help="n-gram speculative-decoding arm with K drafts")
    ap.add_argument("--draft", default="ngram", choices=["ngram"])
    ap.add_argument("--ngram", type=int, default=3)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="> 0 adds per-request-sampled arms (each request "
                         "gets its own seed) with invariance gates")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--kv-dtype", default="fp16",
                    choices=["fp16", "int8", "fp8"],
                    help="paged KV pool storage dtype for every engine "
                         "arm (fp16 = the bit-identical default; the "
                         "identity gates vs generate() are only defined "
                         "at fp16)")
    ap.add_argument("--host-cache-blocks", type=int, default=None,
                    help="host-RAM spill-tier capacity for the tiered "
                         "shared-prefix arm (default: sized to hold "
                         "every tiered-arm prefix chain twice over)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fixed-seed CI gate: shrink the workload "
                         "and assert acceptance > 0 + greedy identity")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args(argv)

    if args.workload == "long-context":
        # its own arm: scaling sweep + identity/memory gates, no seed
        # baseline, and none of the smoke-mode workload rewrites below
        return _run_long_context(args)

    if args.workload == "bursty":
        # its own arm: autoscaled cluster vs fixed replica on modulated-
        # Poisson traffic with scale/identity gates, no seed baseline
        return _run_bursty(args)

    if args.smoke and args.replicas > 1:
        # the 2-replica router gate: multi-tenant traffic (the workload
        # that separates prefix-affinity from round-robin), small
        # replicas so placement outlives the blind warm-up phase, and
        # sampled + speculative arms so every cluster identity gate runs
        args.workload = "multi-tenant"
        args.requests = min(args.requests, 16)
        args.tenants = 3
        args.prefix_len = 16
        args.suffix_len = (2, 6)
        args.max_new = (4, 8)
        args.slots = min(args.slots, 2)
        args.block_size = min(args.block_size, 4)
        args.prefill_batch = min(args.prefill_batch, 2)
        if args.speculate == 0:
            args.speculate = 2
        if args.temperature == 0.0:
            args.temperature = 0.8
        if args.top_k == 0:
            args.top_k = 2
    elif args.smoke:
        # the acceptance-rate gate is only meaningful where n-gram
        # lookup can hit — pin the workload the gate is defined on
        args.workload = "repetitive"
        args.requests = min(args.requests, 6)
        args.prompt_len = [24]
        args.max_new = (8, 16)
        args.slots = min(args.slots, 3)
        args.block_size = min(args.block_size, 4)
        if args.speculate == 0:
            args.speculate = 4
        if args.temperature == 0.0:
            # the sampled-speculation gates need a sampled arm
            args.temperature = 0.8
        if args.top_k == 0:
            # concentrate the sampled stream so n-gram lookup recurs:
            # with an unwarped near-uniform tiny-model distribution the
            # proposer never matches and the acceptance gate is vacuous
            args.top_k = 2

    cfg = get_config(args.arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _make_requests(args, cfg)
    max_seq = max(len(r.prompt) for r in reqs) + max(args.max_new) + 1

    # warm the baseline on the exact workload shapes too
    run_baseline(params, cfg, reqs, args.slots)
    base_tok, base_s = run_baseline(params, cfg, reqs, args.slots)

    (eng_tok, eng_s, eng_stats, eng_done), _ = _measure_engine(
        params, cfg, args, reqs, max_seq, prefix_cache=None)
    sp_done = sm_done = None          # single-replica spec / sampled
    sreqs = None                      # outputs (cluster identity refs)

    base_tps = base_tok / base_s
    eng_tps = eng_tok / eng_s
    record = {
        "meta": _run_meta(args),
        "arch": args.arch,
        "workload": args.workload,
        "requests": args.requests,
        "prompt_lens": sorted({len(r.prompt) for r in reqs}),
        "max_new": list(args.max_new),
        "slots": args.slots,
        "block_size": args.block_size,
        # pool provenance: per-arm byte/occupancy detail lives in each
        # arm's own stats["kv"] block (summarize() emits it)
        "kv_dtype": args.kv_dtype,
        "baseline": {"useful_tokens": base_tok, "wall_s": round(base_s, 3),
                     "tokens_per_s": round(base_tps, 2)},
        "engine": eng_stats,
        "speedup": round(eng_tps / base_tps, 2),
    }
    # ---- observability arm (recorder ON; not a perf arm) ------------
    # gates the bit-identity contract — tracing must never change
    # output — checks that the emitted-token counter reconciles with
    # completion totals, and embeds the metrics snapshot in the record
    obs = Observability()
    obs_engine = ServingEngine(params, cfg, num_slots=args.slots,
                               block_size=args.block_size,
                               max_seq_len=max_seq,
                               num_blocks=_pool_blocks(args, max_seq),
                               prefill_max_batch=args.prefill_batch,
                               obs=obs)
    obs_done = obs_engine.run(list(reqs))
    obs_ref = {c.rid: c.tokens for c in eng_done}
    obs_identical = ({c.rid for c in obs_done} == set(obs_ref) and all(
        np.array_equal(obs_ref[c.rid], c.tokens) for c in obs_done))
    mdump = metrics_dump(obs)
    trace = to_perfetto(obs)
    emitted = obs.registry.total("tokens_emitted_total")
    obs_gen = sum(len(c.tokens) for c in obs_done)
    record["observability"] = {
        "trace_identical": obs_identical,
        "trace_events": len(trace["traceEvents"]),
        "trace_schema_errors": validate_trace_events(trace),
        "metrics_schema_errors": validate_metrics_dump(mdump),
        "tokens_counter_reconciles": emitted == obs_gen,
        "metrics": mdump,
    }
    print(f"obs_trace_identical,{obs_identical},recorder on vs off")
    print(f"obs_tokens_counter,{emitted},vs {obs_gen} completion tokens")
    if args.smoke:
        assert obs_identical, "tracing changed engine output"
        assert emitted == obs_gen, \
            "tokens_emitted_total does not reconcile with completions"
        assert not record["observability"]["trace_schema_errors"], \
            "trace export failed schema validation"
        assert not record["observability"]["metrics_schema_errors"], \
            "metrics dump failed schema validation"
    if args.workload == "shared-prefix":
        (_, _, nocache, _), _ = _measure_engine(
            params, cfg, args, reqs, max_seq, prefix_cache=False)
        record["engine_no_prefix_cache"] = nocache
        record["prefill_tokens_saved"] = (
            nocache["prefill"]["computed_tokens"]
            - eng_stats["prefill"]["computed_tokens"])
        # ---- tiered-KV arm: the same traffic shape but with enough
        # distinct system prompts that the device pool cannot keep
        # every prefix chain resident between reuses — both arms run
        # TWO slots so the slots-only pool is genuinely tight (at the
        # full slot count the default pool has enough slack to keep
        # all chains resident and the tier never engages).
        # Device-only loses an evicted chain for good and re-prefills
        # it; the host tier demotes evicted chains to RAM and revives
        # them on the next prefix hit. Gated on bit-identity (the
        # spill tier moves bytes, never changes them), at least one
        # actual revival, and a strict cached-token gain. ----------
        n_tiered = max(args.n_prefixes, 4)
        targs = argparse.Namespace(**vars(args))
        targs.slots = min(args.slots, 2)
        treqs = shared_prefix_requests(
            args.requests, vocab_size=cfg.vocab_size,
            prefix_len=args.prefix_len,
            suffix_len=tuple(args.suffix_len), max_new=tuple(args.max_new),
            n_prefixes=n_tiered, seed=args.seed)
        host_blocks = args.host_cache_blocks
        if host_blocks is None:
            per_prefix = -(-args.prefix_len // args.block_size)
            host_blocks = 2 * n_tiered * per_prefix
        (_, _, dev_stats, dev_done), _ = _measure_engine(
            params, cfg, targs, treqs, max_seq, prefix_cache=True)
        (_, _, tier_stats, tier_done), _ = _measure_engine(
            params, cfg, targs, treqs, max_seq, prefix_cache=True,
            host_cache_blocks=host_blocks)
        tier_ref = {c.rid: c.tokens for c in dev_done}
        tier_identical = ({c.rid for c in tier_done} == set(tier_ref)
                          and all(np.array_equal(tier_ref[c.rid], c.tokens)
                                  for c in tier_done))
        gained = (tier_stats["prefill"]["cached_tokens"]
                  - dev_stats["prefill"]["cached_tokens"])
        record["engine_tiered_device_only"] = dev_stats
        record["engine_tiered"] = tier_stats
        record["tiered_gate"] = {
            "n_prefixes": n_tiered,
            "slots": targs.slots,
            "host_cache_blocks": host_blocks,
            "greedy_identical": tier_identical,
            "host_revivals": tier_stats["kv"]["host_revivals"],
            "host_demotions": tier_stats["kv"]["host_demotions"],
            "cached_tokens_gained": gained,
        }
        record["tiered_cached_tokens_gained"] = gained
        print(f"tiered_cached_tokens,{tier_stats['prefill']['cached_tokens']},"
              f"vs {dev_stats['prefill']['cached_tokens']} device-only "
              f"({tier_stats['kv']['host_revivals']} host revivals)")
        print(f"tiered_identical,{tier_identical},host tier vs device-only")
        # deterministic (fixed seed, arrivals at t=0) — gate every run,
        # not only --smoke: a spill tier that changes tokens or never
        # revives is broken regardless of run size
        assert tier_identical, "host tier changed greedy output"
        assert record["tiered_gate"]["host_revivals"] >= 1, \
            "host tier never revived a block"
        assert gained > 0, "host tier recovered no cached prompt tokens"
    if args.speculate > 0:
        (sp_tok, sp_s, sp_stats, sp_done), sp_engine = _measure_engine(
            params, cfg, args, reqs, max_seq, prefix_cache=None,
            speculate=args.speculate)
        sp_tps = sp_tok / sp_s
        sp = sp_stats["speculation"]
        # the correctness gate: speculation must never change greedy
        # output, and verify compiles stay within the bucket grid
        identical = _check_identity(params, cfg, reqs, sp_done)
        shapes_ok = (len(sp_engine.runner.verify_shapes)
                     <= len(sp_engine.runner.verify_buckets))
        bucket_ok = all(
            t == pick_bucket(t, sp_engine.runner.verify_buckets)
            for t in sp_engine.runner.verify_shapes)
        record["engine_speculative"] = sp_stats
        record["speculation_gate"] = {
            "greedy_identical": identical,
            "verify_shapes_bounded": shapes_ok and bucket_ok,
        }
        record["spec_speedup"] = round(sp_tps / eng_tps, 2)
        print(f"spec_engine_tok_s,{sp_tps:.1f},")
        print(f"spec_acceptance_rate,{sp['acceptance_rate']},"
              f"{sp['accepted_tokens']} of {sp['proposed_tokens']} drafts")
        print(f"spec_tokens_per_dispatch,{sp['tokens_per_dispatch']},"
              f"vs {eng_stats.get('decode_steps', 0)} plain decode steps")
        print(f"spec_speedup,{record['spec_speedup']},"
              f"x over non-speculative engine")
        print(f"spec_greedy_identical,{identical},")
        if args.smoke:
            assert identical, "speculation changed greedy output"
            if args.workload == "repetitive":
                # the acceptance gate is defined on repetitive traffic;
                # on other smoke workloads n-gram lookup may never hit
                assert sp["acceptance_rate"] > 0, "no draft accepted"
            assert shapes_ok and bucket_ok, "verify shapes escaped grid"
    if args.temperature > 0:
        base_sp = SamplingParams(temperature=args.temperature,
                                 top_k=args.top_k, top_p=args.top_p,
                                 seed=args.seed)
        sreqs = _make_requests(args, cfg, sampling=base_sp)
        record["sampling"] = {
            "temperature": args.temperature, "top_k": args.top_k,
            "top_p": args.top_p, "per_request_seed": True}
        probes = None if args.smoke else 2
        (sm_tok, sm_s, sm_stats, sm_done), sm_engine = _measure_engine(
            params, cfg, args, sreqs, max_seq, prefix_cache=None)
        invariant = _check_batch_invariance(sm_engine, sreqs, sm_done,
                                            probes)
        record["engine_sampled"] = sm_stats
        gate = {"batch_invariant": invariant}
        print(f"sampled_engine_tok_s,{sm_tok / sm_s:.1f},"
              f"temperature {args.temperature}")
        print(f"sampled_batch_invariant,{invariant},"
              f"solo rerun == batched output")
        if args.speculate > 0:
            (ss_tok, ss_s, ss_stats, ss_done), ss_engine = _measure_engine(
                params, cfg, args, sreqs, max_seq, prefix_cache=None,
                speculate=args.speculate)
            ssp = ss_stats["speculation"]
            ss_invariant = _check_batch_invariance(ss_engine, sreqs,
                                                   ss_done, probes)
            record["engine_spec_sampled"] = ss_stats
            gate["spec_sampled_acceptance"] = ssp["acceptance_rate"]
            gate["spec_sampled_batch_invariant"] = ss_invariant
            print(f"spec_sampled_acceptance_rate,{ssp['acceptance_rate']},"
                  f"{ssp['accepted_tokens']} of {ssp['proposed_tokens']} "
                  f"drafts (Leviathan accept/reject)")
            print(f"spec_sampled_batch_invariant,{ss_invariant},")
        record["sampling_gate"] = gate
        if args.smoke:
            assert invariant, "sampled output depends on batch composition"
            if args.workload == "repetitive":
                assert gate.get("spec_sampled_acceptance", 1) > 0, \
                    "speculative sampling accepted no draft"
            assert gate.get("spec_sampled_batch_invariant", True), \
                "spec-sampled output depends on batch composition"
    if args.replicas > 1:
        # -------------------- cluster arms ---------------------------
        # the same workload through a Router over N replica stacks,
        # once per policy; every arm gated on bit-identity to the
        # single-replica run above (greedy / sampled / speculative)
        cluster = {"replicas": args.replicas, "arms": {}}
        reps = _cluster_replicas(params, cfg, args, max_seq)
        reps_spec = None
        if args.speculate > 0:
            reps_spec = _cluster_replicas(params, cfg, args, max_seq,
                                          speculate=args.speculate)
        for policy in POLICIES:
            done_c, router = _measure_cluster(reps, reqs, policy)
            cs = summarize_cluster(done_c, router.wall_time, router)
            arm = {
                "tokens_per_s": cs["tokens_per_s"],
                "placed": cs["cluster"]["placed"],
                "prompt_tokens": cs["cluster"]["prompt_tokens"],
                "cached_prompt_tokens":
                    cs["cluster"]["cached_prompt_tokens"],
                "greedy_identical": _cluster_identical(done_c, eng_done),
            }
            if sm_done is not None:
                sdone, _ = _measure_cluster(reps, sreqs, policy)
                arm["sampled_identical"] = _cluster_identical(sdone,
                                                              sm_done)
            if sp_done is not None:
                pdone, _ = _measure_cluster(reps_spec, reqs, policy)
                arm["spec_identical"] = _cluster_identical(pdone, sp_done)
            cluster["arms"][policy] = arm
            ident = all(v for k, v in arm.items() if k.endswith("identical"))
            print(f"cluster_{policy}_tok_s,{arm['tokens_per_s']},"
                  f"{args.replicas} replicas")
            print(f"cluster_{policy}_cached_tokens,"
                  f"{arm['cached_prompt_tokens']},"
                  f"of {arm['prompt_tokens']} prompt tokens "
                  f"(placed {arm['placed']})")
            print(f"cluster_{policy}_identical,{ident},"
                  f"vs single-replica run (all arms)")
        record["cluster"] = cluster
        arms = cluster["arms"]
        affinity_gap = (arms["prefix-affinity"]["cached_prompt_tokens"]
                        - arms["round-robin"]["cached_prompt_tokens"])
        cluster["affinity_cached_tokens_over_rr"] = affinity_gap
        print(f"cluster_affinity_cached_over_rr,{affinity_gap},"
              f"prefix-affinity cache-skips vs round-robin")
        if args.smoke:
            for policy, arm in arms.items():
                for key in ("greedy_identical", "sampled_identical",
                            "spec_identical"):
                    assert arm.get(key, True), \
                        f"{policy} cluster {key} gate failed"
            assert affinity_gap > 0, \
                "prefix-affinity did not out-cache round-robin"
    print(f"serving_baseline_tok_s,{base_tps:.1f},")
    print(f"serving_engine_tok_s,{eng_tps:.1f},")
    print(f"serving_speedup,{record['speedup']:.2f},x over token-by-token")
    pf = eng_stats["prefill"]
    print(f"prefill_computed_tokens,{pf['computed_tokens']},"
          f"of {pf['prompt_tokens']} prompt tokens "
          f"({pf['cached_tokens']} cached)")
    print(f"prefill_jit_shapes,{pf['shapes']},"
          f"vs {len(record['prompt_lens'])} distinct prompt lengths "
          f"(bucket bound {pf['buckets']})")
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out,
                        f"bench_{args.arch}_{args.workload}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {path}")
    return record


def main():
    run_bench()


if __name__ == "__main__":
    main()
