"""Roofline report: reads experiments/dryrun/*.json (written by
repro.launch.dryrun) and prints the per-(arch x shape x mesh) three-term
roofline table used by EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_cells(mesh: str | None = None):
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if mesh and rec.get("mesh") != mesh:
            continue
        cells.append(rec)
    return cells


def run():
    cells = load_cells()
    if not cells:
        emit("roofline/missing", 0.0,
             "run `python -m repro.launch.dryrun` first")
        return
    for rec in cells:
        cell = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec["status"] == "skipped":
            emit(f"roofline/{cell}", 0.0, "skipped=" + rec["reason"][:40])
            continue
        if rec["status"] != "ok":
            emit(f"roofline/{cell}", 0.0, "ERROR")
            continue
        r = rec["roofline"]
        step_us = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6
        emit(f"roofline/{cell}", step_us,
             f"bottleneck={r['bottleneck']};mfu_bound={r['mfu_bound']:.3f};"
             f"useful_ratio={r['useful_ratio']:.3f};"
             f"fits16gb={rec['memory']['fits_16gb']}")


def markdown_table(mesh="single"):
    """Baseline table for EXPERIMENTS.md (one canonical variant per cell:
    'mbprox' for train, 'serve' for inference; opt/baseline variants are
    §Perf material)."""
    rows = ["| arch | shape | compute_s | memory_s | collective_s | "
            "bottleneck | MODEL_FLOPS | useful ratio | MFU bound | mem/dev "
            "(adj GB) |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for rec in load_cells(mesh):
        if rec.get("variant") not in ("mbprox", "serve"):
            continue
        if rec["status"] == "skipped":
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"skipped (full attention @500k) | — | — | — | — |")
            continue
        if rec["status"] != "ok":
            continue
        r = rec["roofline"]
        m = rec["memory"]
        # clamp: adjusted residency can't be below declared args+outputs
        floor = round((m.get("argument_size_in_bytes", 0)
                       + m.get("output_size_in_bytes", 0)
                       - m.get("alias_size_in_bytes", 0)) / 1024**3, 2)
        adj = max(m.get("tpu_adjusted_total_gb", m.get("total_gb")), floor)
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"**{r['bottleneck']}** | {r['model_flops']:.2e} | "
            f"{r['useful_ratio']:.3f} | {r['mfu_bound']:.3f} | {adj} |")
    return "\n".join(rows)


if __name__ == "__main__":
    run()
