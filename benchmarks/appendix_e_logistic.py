"""App. E analog: MP-DANE with SAGA local solver on logistic classification
(the paper's experimental setup used logistic loss on libsvm datasets; we
use a synthetic separable-with-noise task so the benchmark is hermetic).

Observations to reproduce: (i) MP-DANE degrades slowly in b, minibatch SGD
quickly; (ii) more DANE iterations K help with diminishing returns."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import theory
from repro.core.losses import logistic
from repro.core.mp_dane import run_mp_dane
from repro.core.baselines import run_minibatch_sgd
from repro.data.synthetic import LeastSquaresStream


class LogisticStream(LeastSquaresStream):
    """y in {-1, +1} from a noisy linear teacher."""

    def sample(self, key, n):
        X, _ = super().sample(key, n)
        margin = X @ self.w_star()
        kf = jax.random.fold_in(key, 99)
        flip = jax.random.bernoulli(kf, 0.05, (n,))
        y = jnp.where(flip, -jnp.sign(margin), jnp.sign(margin))
        return X, jnp.where(y == 0, 1.0, y)

    def population_logloss(self, w, n_eval=32768):
        X, y = self.sample(jax.random.PRNGKey(10**6), n_eval)
        return float(jnp.mean(jnp.logaddexp(0.0, -y * (X @ w))))


def run():
    stream = LogisticStream(dim=32, noise=0.0, seed=0)
    spec = theory.ProblemSpec(L=2.0, beta=0.5, B=2.0, dim=32)
    m, n_local = 4, 1024
    loss = logistic()
    for b in [64, 256, 1024]:
        T = n_local // b
        t0 = time.perf_counter()
        res = run_mp_dane(stream, spec, m, b, T, K=4, R=1, kappa=0.0,
                          local_solver="prox_svrg", eta_scale=0.3,
                          loss=loss)
        us = (time.perf_counter() - t0) * 1e6
        ll = stream.population_logloss(res.w_avg)
        emit(f"appE/logistic_mp_dane/b={b}", us, f"logloss={ll:.4f}")
        t0 = time.perf_counter()
        sgd = run_minibatch_sgd(stream, spec, m, b, T, loss=loss)
        us = (time.perf_counter() - t0) * 1e6
        ll = stream.population_logloss(sgd.w_avg)
        emit(f"appE/logistic_minibatch_sgd/b={b}", us, f"logloss={ll:.4f}")


if __name__ == "__main__":
    run()
