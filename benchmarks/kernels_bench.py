"""Kernel microbenchmarks: jnp reference wall time on CPU + analytic HBM
traffic saved by the fused/blocked Pallas versions (real speedups require
TPU; interpret mode is a correctness emulator, not a performance path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.kernels import ref

K = jax.random.PRNGKey(0)


def run():
    # svrg fused update: 6 HBM passes -> 1
    n = 1 << 20
    args = [jax.random.normal(jax.random.fold_in(K, i), (n,))
            for i in range(5)]
    fn = jax.jit(lambda *a: ref.svrg_update_ref(*a, 0.1, 0.5))
    us = time_call(fn, *args)
    emit("kernel/svrg_update_ref_1M", us,
         f"traffic_unfused={6 * 4 * n};traffic_fused={6 * 4 * n // 6 * 2}")

    # flash attention vs materializing ref at 2k
    B, H, KV, S, hd = 1, 8, 2, 2048, 64
    q = jax.random.normal(jax.random.fold_in(K, 10), (B, H, S, hd),
                          jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(K, 11), (B, KV, S, hd),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(K, 12), (B, KV, S, hd),
                          jnp.bfloat16)
    fn = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us = time_call(fn, q, k, v)
    scores_bytes = 4 * B * H * S * S * 3
    emit("kernel/attention_ref_2k", us,
         f"scores_traffic_removed_by_flash={scores_bytes}")

    # rwkv6: per-token state traffic removed by VMEM-resident kernel
    B, H, T, N = 2, 8, 512, 64
    r, kk, v = (jax.random.normal(jax.random.fold_in(K, 20 + i),
                                  (B, H, T, N)) * 0.5 for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(K, 24),
                                         (B, H, T, N)))
    u = jax.random.normal(jax.random.fold_in(K, 25), (H, N)) * 0.1
    fn = jax.jit(lambda *a: ref.rwkv6_ref(*a)[0])
    us = time_call(fn, r, kk, v, w, u)
    emit("kernel/rwkv6_ref_512", us,
         f"state_traffic_removed={2 * 4 * B * H * N * N * T}")

    # rg-lru
    B, T, C = 2, 1024, 512
    a = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(K, 30),
                                         (B, T, C)))
    x = jax.random.normal(jax.random.fold_in(K, 31), (B, T, C)) * 0.3
    fn = jax.jit(lambda *args: ref.rglru_ref(*args)[0])
    us = time_call(fn, a, x)
    emit("kernel/rglru_ref_1k", us,
         f"state_traffic_removed={2 * 4 * B * C * T}")


if __name__ == "__main__":
    run()
