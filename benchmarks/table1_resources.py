"""Table 1: resources (communication / computation / memory) per method,
measured by the accounting ledger and checked against the theory model."""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.core import theory
from repro.core.baselines import (run_acc_minibatch_sgd, run_dsvrg_erm,
                                  run_emso, run_minibatch_sgd)
from repro.core.losses import loss_constants
from repro.core.mp_dane import run_mp_dane
from repro.core.mp_dsvrg import run_mp_dsvrg
from repro.data.synthetic import LeastSquaresStream


def run():
    stream = LeastSquaresStream(dim=32, noise=0.1, seed=0)
    X, y = stream.sample(jax.random.PRNGKey(1), 4096)
    L, beta = loss_constants(X, y, radius=1.0)
    spec = theory.ProblemSpec(L=L, beta=beta, B=1.0, dim=32)
    m, b, T = 4, 128, 8
    n = b * m * T

    methods = [
        ("mp_dsvrg", lambda: run_mp_dsvrg(stream, spec, m, b, T)),
        ("mp_dane", lambda: run_mp_dane(stream, spec, m, b, T,
                                        local_solver="exact")),
        ("emso", lambda: run_emso(stream, spec, m, b, T)),
        ("minibatch_sgd", lambda: run_minibatch_sgd(stream, spec, m, b, T)),
        ("acc_minibatch_sgd",
         lambda: run_acc_minibatch_sgd(stream, spec, m, b, T)),
        ("dsvrg_erm", lambda: run_dsvrg_erm(stream, spec, m, n // m)),
    ]
    for name, fn in methods:
        t0 = time.perf_counter()
        res = fn()
        us = (time.perf_counter() - t0) * 1e6
        sub = float(stream.population_suboptimality(res.w_avg))
        led = res.ledger
        emit(f"table1/{name}", us,
             f"subopt={sub:.5f};comm={led.comm_rounds};"
             f"mem={led.peak_memory_vectors};ops={led.vector_ops}")


if __name__ == "__main__":
    run()
