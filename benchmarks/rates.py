"""Theorems 4/5/7: empirical suboptimality vs the proved bounds."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import theory
from repro.core.losses import loss_constants
from repro.core.minibatch_prox import run_minibatch_prox
from repro.data.synthetic import LeastSquaresStream


def run():
    stream = LeastSquaresStream(dim=32, noise=0.1, seed=0)
    X, y = stream.sample(jax.random.PRNGKey(1), 4096)
    L, beta = loss_constants(X, y, radius=1.0)
    spec = theory.ProblemSpec(L=L, beta=beta, B=1.0, dim=32)

    # Thm 4 (exact, weakly convex): same bT => same error; bound holds
    for (b, T) in [(32, 32), (128, 8), (512, 2)]:
        t0 = time.perf_counter()
        res = run_minibatch_prox(stream, spec, b, T, solver="exact")
        us = (time.perf_counter() - t0) * 1e6
        sub = float(stream.population_suboptimality(res.w_avg))
        bound = theory.rate_bound_weakly_convex(spec, b, T)
        emit(f"thm4/b={b},T={T}", us,
             f"subopt={sub:.5f};bound={bound:.5f};ok={sub <= bound}")

    # Thm 7 (inexact)
    for solver in ("prox_svrg", "saga"):
        t0 = time.perf_counter()
        res = run_minibatch_prox(stream, spec, 128, 8, solver=solver)
        us = (time.perf_counter() - t0) * 1e6
        sub = float(stream.population_suboptimality(res.w_avg))
        bound = theory.rate_bound_weakly_convex(spec, 128, 8, exact=False)
        emit(f"thm7/{solver}", us,
             f"subopt={sub:.5f};bound={bound:.5f};ok={sub <= bound}")

    # Thm 5 (strongly convex)
    lam = 0.5
    Xs, ys = stream.sample(jax.random.PRNGKey(1), 4096)
    L2, beta2 = loss_constants(Xs, ys, radius=1.0, lam=lam)
    spec_sc = theory.ProblemSpec(L=L2, beta=beta2, B=1.0, lam=lam, dim=32)
    t0 = time.perf_counter()
    res = run_minibatch_prox(stream, spec_sc, 64, 16, solver="exact",
                             strongly_convex=True, lam=lam)
    us = (time.perf_counter() - t0) * 1e6
    Xe, ye = stream.sample(jax.random.PRNGKey(10**6), 65536)
    H = Xe.T @ Xe / Xe.shape[0] + lam * jnp.eye(32)
    w_opt = jnp.linalg.solve(H, Xe.T @ ye / Xe.shape[0])

    def phi(w):
        r = Xe @ w - ye
        return 0.5 * jnp.mean(r * r) + 0.5 * lam * jnp.dot(w, w)

    sub = float(phi(res.w_avg) - phi(w_opt))
    bound = theory.rate_bound_strongly_convex(spec_sc, 64, 16)
    emit("thm5/strongly_convex", us,
         f"subopt={sub:.6f};bound={bound:.6f};ok={sub <= bound}")


if __name__ == "__main__":
    run()
