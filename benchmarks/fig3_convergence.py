"""Figure 3 / App. E: population objective vs minibatch size for MP-DANE
(K in {1,4}) against minibatch SGD — MP degrades slowly in b, SGD quickly."""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.core import theory
from repro.core.baselines import run_minibatch_sgd
from repro.core.losses import loss_constants
from repro.core.mp_dane import run_mp_dane
from repro.data.synthetic import LeastSquaresStream


def run():
    stream = LeastSquaresStream(dim=32, noise=0.1, seed=0)
    X, y = stream.sample(jax.random.PRNGKey(1), 4096)
    L, beta = loss_constants(X, y, radius=1.0)
    spec = theory.ProblemSpec(L=L, beta=beta, B=1.0, dim=32)
    m, n_local = 4, 1024
    for b in [64, 256, 1024]:
        T = n_local // b
        for K in (1, 4):
            t0 = time.perf_counter()
            res = run_mp_dane(stream, spec, m, b, T, K=K, R=1, kappa=0.0,
                              local_solver="saga", eta_scale=0.1)
            us = (time.perf_counter() - t0) * 1e6
            sub = float(stream.population_suboptimality(res.w_avg))
            emit(f"fig3/mp_dane_K{K}/b={b}", us, f"subopt={sub:.5f}")
        t0 = time.perf_counter()
        sgd = run_minibatch_sgd(stream, spec, m, b, T)
        us = (time.perf_counter() - t0) * 1e6
        sub = float(stream.population_suboptimality(sgd.w_avg))
        emit(f"fig3/minibatch_sgd/b={b}", us, f"subopt={sub:.5f}")


if __name__ == "__main__":
    run()
